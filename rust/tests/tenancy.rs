//! §Multi-tenancy isolation suite — the fairness invariants the tenant
//! layer promises, pinned as executable properties:
//!
//! 1. **Isolation**: a misbehaving flash-crowd tenant (8× arrival burst via
//!    the MMPP model) cannot move a well-behaved tenant's p99 beyond a
//!    stated bound, across seeds.
//! 2. **Weighted-share conservation**: under saturation, served work per
//!    tenant converges to the DRR weight vector within tolerance.
//! 3. **Starvation-freedom**: every backlogged tenant with nonzero weight
//!    is dispatched at least once every `K = 1 + Σ other weights`
//!    dispatch opportunities (quantum = per-request cost here, so the
//!    classic DRR round bound is exact).
//!
//! Plus the standing off-path contract: with tenancy off the report carries
//! exactly the pre-tenancy key set, and a *neutral* config (one tenant,
//! weight 1, no quota, floor 0, unbounded depth) reproduces the tenancy-off
//! scheduling decisions bit for bit — the serialized reports differ only by
//! the gated tenant keys.

use hsv::balancer::DispatchPolicy;
use hsv::config::{HardwareConfig, SimConfig};
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AdmissionPolicy, AutoscalePolicy, BatchPolicy, ServeConfig, ServeEngine, ServeReport,
    ShedReason, SloPolicy, TenancyConfig, TenantSpec,
};
use hsv::util::json::Json;
use hsv::util::quick;
use hsv::workload::{ArrivalModel, ModelRegistry, Workload, WorkloadRequest, WorkloadSpec};

fn engine(clusters: u32) -> ServeEngine {
    ServeEngine::new(
        HardwareConfig::small().with_clusters(clusters),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig {
            policy: DispatchPolicy::LeastLoaded,
            slo: SloPolicy::default(),
            batch: BatchPolicy::Off,
            admission: AdmissionPolicy::Open,
            autoscale: AutoscalePolicy::Off,
            ..Default::default()
        },
    )
}

/// A hand-built single-model trace: `n` requests of `model`, one every
/// `gap` cycles, tagged `tenant`, ids starting at `id0`.
fn uniform_trace(model: u32, n: usize, gap: u64, tenant: u32, id0: u64) -> Vec<WorkloadRequest> {
    (0..n)
        .map(|i| WorkloadRequest::new(id0 + i as u64, model, gap * i as u64).with_tenant(tenant))
        .collect()
}

fn wl_of(name: &str, requests: Vec<WorkloadRequest>) -> Workload {
    Workload {
        name: name.to_string(),
        cnn_ratio: 0.0,
        seed: 0,
        requests,
        registry: ModelRegistry::standard(),
    }
}

/// The registry model with the fewest ops (cheap, fast isolated service).
fn lightest_model(reg: &ModelRegistry) -> u32 {
    (0..reg.len() as u32).min_by_key(|&id| reg.total_ops(id)).unwrap()
}

/// The registry model with the most ops — its cost equals the DRR quantum,
/// so a weight-w tenant dispatches exactly w heads per fresh cursor visit.
fn heaviest_model(reg: &ModelRegistry) -> u32 {
    (0..reg.len() as u32).max_by_key(|&id| reg.total_ops(id)).unwrap()
}

/// Served requests in dispatch order: `(tenant, request_id)` sorted by
/// `(dispatched_at, request_id)` — the sequence the DRR cursor produced.
fn dispatch_order(rep: &ServeReport) -> Vec<(u32, u64)> {
    let mut v: Vec<(u64, u64, u32)> =
        rep.served.iter().map(|r| (r.dispatched_at, r.request_id, r.tenant)).collect();
    v.sort();
    v.into_iter().map(|(_, id, t)| (t, id)).collect()
}

fn json_keys(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(m) => m.keys().cloned().collect(),
        _ => panic!("report JSON must be an object"),
    }
}

/// Property 1 — isolation. A well-behaved tenant (one request every
/// 4 isolated-service-times, so ~25% solo load) shares the fleet with a
/// flash crowd arriving 8× faster via the MMPP bursty model (bursts go
/// 16×). With the crowd held to quota 2 and fair dispatch at depth 2, at
/// most 2 crowd requests exist anywhere in the system when a victim
/// request lands, so the victim waits at most a couple of crowd service
/// times beyond its solo baseline. Stated bound, checked across seeds:
///
///   p99(victim | attacked) ≤ p99(victim | solo) + 8 × t_iso
///
/// where t_iso is the measured isolated latency of the victim's model.
#[test]
fn flash_crowd_cannot_move_victim_p99_beyond_bound() {
    let reg = ModelRegistry::standard();
    let m = lightest_model(&reg);
    // Measure the isolated service time on the same fleet.
    let iso = engine(2).run(&wl_of("iso", uniform_trace(m, 1, 1, 0, 0)));
    assert_eq!(iso.served.len(), 1);
    let t_iso_cycles = iso.served[0].latency.max(1);
    let t_iso_ms = iso.p99_ms();
    assert!(t_iso_ms > 0.0);
    let gap = 4 * t_iso_cycles;
    let victim = wl_of("victim", uniform_trace(m, 24, gap, 0, 0));
    let solo = engine(2).run(&victim);
    assert_eq!(solo.served.len(), 24);
    let bound = solo.p99_ms() + 8.0 * t_iso_ms;
    quick::check(0xFA12_C40D, 5, |g| {
        let seed = g.rng.next_u64();
        // The flash crowd: MMPP arrivals whose *normal* rate is already 8×
        // the victim's and whose burst state doubles that again.
        let mut crowd = WorkloadSpec::ratio(0.5, 160, seed)
            .with_arrivals(ArrivalModel::bursty(gap as f64 / 8.0, gap as f64 / 16.0))
            .generate();
        for r in &mut crowd.requests {
            r.model_id = m;
        }
        let merged = Workload::merge_tenants(&[(0, victim.clone()), (1, crowd)]);
        let tcfg = TenancyConfig::new(vec![
            TenantSpec::weighted("victim", 8),
            TenantSpec::weighted("crowd", 1).with_quota(2),
        ])
        .with_depth(2);
        let rep = engine(2).with_tenancy(tcfg).run(&merged);
        assert_eq!(rep.tenant_served(0), 24, "the victim is never shed (seed {seed})");
        // Non-vacuous: the crowd really overran its quota, and only the
        // crowd was shed.
        assert!(rep.tenant_shed(1) > 0, "crowd never hit quota — attack not exercised");
        assert!(rep.shed.iter().all(|s| s.tenant == 1));
        assert!(
            rep.shed.iter().all(|s| s.reason == ShedReason::TenantQuotaExceeded),
            "under Open admission only the quota sheds"
        );
        let p99 = rep.tenant_p99_ms(0);
        assert!(
            p99 <= bound,
            "victim p99 {p99:.4}ms beyond bound {bound:.4}ms (solo {:.4}ms, t_iso {:.4}ms, seed {seed})",
            solo.p99_ms(),
            t_iso_ms,
        );
        true
    });
}

/// Property 2 — weighted-share conservation. Two tenants, both fully
/// backlogged on the heaviest model (cost == quantum, so deficit rounds
/// dispatch exactly `weight` heads), weights 3:1, one cluster at depth 1.
/// While both stay backlogged the dispatch stream must interleave 3:1: the
/// first 40 dispatches contain tenant 1 ≈ 10 times, and the served-ops
/// ratio over the contended window converges to the weight ratio within
/// tolerance.
#[test]
fn weighted_share_conserves_the_weight_vector_under_saturation() {
    let reg = ModelRegistry::standard();
    let h = heaviest_model(&reg);
    let mut requests = uniform_trace(h, 30, 0, 0, 0);
    requests.extend(uniform_trace(h, 90, 0, 1, 30));
    let wl = wl_of("saturated-3to1", requests);
    let tcfg = TenancyConfig::new(vec![
        TenantSpec::weighted("gold", 3),
        TenantSpec::weighted("silver", 1),
    ])
    .with_depth(1);
    let rep = engine(1).with_tenancy(tcfg).run(&wl);
    assert_eq!(rep.served.len(), 120, "saturation must not lose work");
    let order = dispatch_order(&rep);
    // Tenant 0 stays backlogged through its 30 requests, i.e. through the
    // first ~40 dispatch slots; DRR gives tenant 1 one slot in four there.
    let t1_early = order[..40].iter().filter(|(t, _)| *t == 1).count();
    assert!(
        (8..=14).contains(&t1_early),
        "expected ~10 silver dispatches in the first 40, got {t1_early}: {:?}",
        &order[..40]
    );
    // Served-work ratio over the contended window (up to gold's last
    // dispatch): converges to the 3:1 weight ratio within tolerance.
    let gold_last = order.iter().rposition(|(t, _)| *t == 0).unwrap();
    let window = &order[..=gold_last];
    let gold = window.iter().filter(|(t, _)| *t == 0).count() as f64;
    let silver = window.iter().filter(|(t, _)| *t == 1).count() as f64;
    let ratio = gold / silver.max(1.0);
    assert!(
        (2.0..=4.5).contains(&ratio),
        "served-share ratio {ratio:.2} strayed from the 3:1 weights (gold {gold}, silver {silver})"
    );
    // Uniform model: the ops view tells the same story as the count view.
    assert_eq!(rep.tenant_ops(0), 30 * reg.total_ops(h));
    assert_eq!(rep.tenant_ops(1), 90 * reg.total_ops(h));
}

/// Property 3 — starvation-freedom. Three backlogged tenants with weights
/// 1 / 4 / 8 on the heaviest model (cost == quantum): every tenant must be
/// dispatched at least once every `K = 1 + Σ other weights` dispatch
/// opportunities while it has work — the classic DRR round bound, exact
/// here — and every admitted request is eventually served.
#[test]
fn every_backlogged_tenant_makes_progress_within_k_dispatches() {
    let reg = ModelRegistry::standard();
    let h = heaviest_model(&reg);
    let weights = [1u32, 4, 8];
    let mut requests = Vec::new();
    for (t, _) in weights.iter().enumerate() {
        requests.extend(uniform_trace(h, 24, 0, t as u32, 24 * t as u64));
    }
    let wl = wl_of("three-tenant-backlog", requests);
    let tcfg = TenancyConfig::new(vec![
        TenantSpec::weighted("bronze", weights[0]),
        TenantSpec::weighted("silver", weights[1]),
        TenantSpec::weighted("gold", weights[2]),
    ])
    .with_depth(1);
    let rep = engine(1).with_tenancy(tcfg).run(&wl);
    assert_eq!(rep.served.len(), 72, "no admitted request may starve forever");
    let order = dispatch_order(&rep);
    let total_w: u32 = weights.iter().sum();
    for (t, &w) in weights.iter().enumerate() {
        assert_eq!(rep.tenant_served(t as u32), 24, "tenant {t} lost work");
        let positions: Vec<usize> = order
            .iter()
            .enumerate()
            .filter(|(_, (ten, _))| *ten == t as u32)
            .map(|(i, _)| i)
            .collect();
        let k = (1 + total_w - w) as usize;
        assert!(
            positions[0] < total_w as usize,
            "tenant {t} first dispatched at slot {} — starved through the first round",
            positions[0]
        );
        for pair in positions.windows(2) {
            let gap = pair[1] - pair[0];
            assert!(
                gap <= k,
                "tenant {t} (weight {w}) waited {gap} dispatch slots, bound K = {k}"
            );
        }
    }
}

/// Off-path pin: with no tenancy config the report carries exactly the
/// pre-tenancy key set — not a single tenant key, byte for byte the PR 7
/// shape (the same discipline as the batch/admission/autoscale off-pins).
#[test]
fn tenants_off_report_carries_exactly_the_pre_tenancy_keys() {
    let wl = WorkloadSpec::ratio(0.5, 18, 13)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let rep = engine(2).run(&wl);
    let mut keys = json_keys(&rep.to_json());
    keys.sort();
    let mut expected: Vec<String> = [
        "hw",
        "scheduler",
        "policy",
        "workload",
        "requests",
        "makespan_cycles",
        "tops",
        "goodput_tops",
        "utilization",
        "mean_latency_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p999_ms",
        "deadline_miss_rate",
        "slo_cnn_ms",
        "slo_transformer_ms",
        "epochs",
        "decisions",
        "miss_rate_cnn",
        "miss_rate_transformer",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    expected.sort();
    assert_eq!(keys, expected, "tenancy-off report JSON grew or lost keys");
    assert!(!rep.to_json().to_pretty().contains("tenant"));
    assert!(rep.tenancy.is_none());
    assert!(rep.tenant_counters.is_empty());
}

/// The neutral config (one tenant, weight 1, no quota, floor 0, unbounded
/// depth) takes every tenancy code path — the gate, fair dispatch, the
/// completion debits — yet must reproduce the tenancy-off scheduling
/// decisions bit for bit under the full batching + admission stack; the
/// serialized reports differ exactly by the gated tenant keys.
#[test]
fn neutral_tenancy_schedules_exactly_like_off() {
    let wl = WorkloadSpec::ratio(0.5, 24, 9)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let stack = |tenancy: bool| {
        let mut e = engine(2)
            .with_batch(BatchPolicy::SloAware { max_batch: 4 })
            .with_admission(AdmissionPolicy::DeadlineFeasible);
        if tenancy {
            e = e.with_tenancy(TenancyConfig::neutral());
        }
        e.run(&wl)
    };
    let off = stack(false);
    let neutral = stack(true);
    let records = |r: &ServeReport| {
        r.served
            .iter()
            .map(|s| (s.request_id, s.cluster, s.dispatched_at, s.end))
            .collect::<Vec<_>>()
    };
    assert_eq!(records(&off), records(&neutral), "neutral tenancy steered dispatch");
    assert_eq!(off.makespan, neutral.makespan);
    assert_eq!(off.decisions, neutral.decisions);
    assert_eq!(off.epochs, neutral.epochs);
    assert_eq!(off.deferred, neutral.deferred);
    assert_eq!(
        off.shed.iter().map(|s| (s.request_id, s.reason)).collect::<Vec<_>>(),
        neutral.shed.iter().map(|s| (s.request_id, s.reason)).collect::<Vec<_>>(),
    );
    // The report shape differs from off exactly by the tenant keys (the
    // neutral depth is unbounded, so no tenant_depth key either).
    let (off_j, ten_j) = (off.to_json(), neutral.to_json());
    let mut extra: Vec<String> =
        json_keys(&ten_j).into_iter().filter(|k| off_j.get(k).is_none()).collect();
    extra.sort();
    assert_eq!(extra, vec!["tenant_batching", "tenant_count", "tenants"]);
    for k in json_keys(&off_j) {
        assert_eq!(
            off_j.get(&k).map(|v| v.to_string()),
            ten_j.get(&k).map(|v| v.to_string()),
            "shared key {k} diverged between off and neutral tenancy"
        );
    }
}

/// Same-epoch composition of floors, the shared backlog, and the base
/// policy: tenant 0's admission floor forces three admissions through a
/// `PriorityThreshold` that would otherwise defer to depth, and those
/// forced credits are what push tenant 1's same-epoch release over the
/// policy's depth limit — the engine-level view of the
/// `Backlog::note_admitted` composition the unit tests pin.
#[test]
fn floor_credits_are_visible_to_the_other_tenants_same_epoch_decisions() {
    let reg = ModelRegistry::standard();
    let m = lightest_model(&reg);
    let mut requests = uniform_trace(m, 3, 0, 0, 0);
    requests.extend(uniform_trace(m, 1, 0, 1, 3));
    let wl = wl_of("floor-vs-threshold", requests);
    let tcfg = TenancyConfig::new(vec![
        TenantSpec::weighted("floored", 1).with_floor(3),
        TenantSpec::weighted("plain", 1),
    ]);
    let rep = engine(1)
        .with_admission(AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 2 })
        .with_tenancy(tcfg)
        .run(&wl);
    assert_eq!(rep.tenant_served(0), 3, "the floor must force all three through");
    assert_eq!(rep.tenant_shed(1), 1, "tenant 1 must see depth 3 > max_depth 2 and shed");
    assert_eq!(rep.shed.len(), 1);
    assert_eq!(rep.shed[0].reason, ShedReason::BelowPriorityFloor);
    assert_eq!(rep.shed[0].tenant, 1);
}

/// Boundary at quota == depth: with quota 2 and fair depth 2 on one
/// cluster, the tenant may hold exactly the cluster's open window; the
/// third and fourth same-epoch releases shed at the quota, the first two
/// are served.
#[test]
fn quota_equals_depth_boundary_is_exact() {
    let reg = ModelRegistry::standard();
    let m = lightest_model(&reg);
    let wl = wl_of("quota-at-depth", uniform_trace(m, 4, 0, 0, 0));
    let tcfg =
        TenancyConfig::new(vec![TenantSpec::weighted("capped", 1).with_quota(2)]).with_depth(2);
    let rep = engine(1).with_tenancy(tcfg).run(&wl);
    assert_eq!(rep.tenant_served(0), 2);
    assert_eq!(rep.tenant_shed(0), 2);
    assert!(rep.shed.iter().all(|s| s.reason == ShedReason::TenantQuotaExceeded));
    assert_eq!(rep.tenant_counters.len(), 1);
    assert_eq!(rep.tenant_counters[0].released, 4);
    assert_eq!(rep.tenant_counters[0].admitted, 2);
    assert_eq!(rep.tenant_counters[0].shed, 2);
    assert_eq!(rep.tenant_counters[0].completed, 2);
}

/// Weight ties resolve to the lower tenant id: equal weights alternate
/// deterministically starting at tenant 0, end to end through the engine.
#[test]
fn weight_ties_alternate_starting_at_the_lower_tenant_id() {
    let reg = ModelRegistry::standard();
    let h = heaviest_model(&reg);
    let mut requests = uniform_trace(h, 2, 0, 0, 0);
    requests.extend(uniform_trace(h, 2, 0, 1, 2));
    let wl = wl_of("tie", requests);
    let tcfg = TenancyConfig::new(vec![
        TenantSpec::weighted("a", 1),
        TenantSpec::weighted("b", 1),
    ])
    .with_depth(1);
    let rep = engine(1).with_tenancy(tcfg).run(&wl);
    let tenants: Vec<u32> = dispatch_order(&rep).iter().map(|(t, _)| *t).collect();
    assert_eq!(tenants, vec![0, 1, 0, 1], "1:1 weights must alternate from tenant 0");
}

/// The cross-tenant batching knob: with fusing on (the default) a same-
/// model, same-epoch pair of tenants coalesces into one mixed batch; with
/// isolation on every batch is tenant-pure — at the cost of smaller
/// batches, never of lost work.
#[test]
fn batching_isolation_knob_controls_cross_tenant_fusing() {
    let reg = ModelRegistry::standard();
    let m = lightest_model(&reg);
    // Interleaved ids so the fused coalescing queue necessarily mixes
    // tenants regardless of flush order.
    let requests = vec![
        WorkloadRequest::new(0, m, 0).with_tenant(0),
        WorkloadRequest::new(1, m, 0).with_tenant(1),
        WorkloadRequest::new(2, m, 0).with_tenant(0),
        WorkloadRequest::new(3, m, 0).with_tenant(1),
    ];
    let wl = wl_of("batch-mix", requests);
    let specs = || {
        vec![TenantSpec::weighted("a", 1), TenantSpec::weighted("b", 1)]
    };
    let run = |fuse: bool| {
        engine(1)
            .with_batch(BatchPolicy::SloAware { max_batch: 4 })
            .with_tenancy(TenancyConfig::new(specs()).with_fuse_across_tenants(fuse))
            .run(&wl)
    };
    let batch_tenants = |rep: &ServeReport| {
        let mut by_batch: std::collections::BTreeMap<u64, Vec<u32>> =
            std::collections::BTreeMap::new();
        for r in rep.served.iter().filter(|r| r.batch.is_some()) {
            by_batch.entry(r.batch.unwrap()).or_default().push(r.tenant);
        }
        by_batch
    };
    let fused = run(true);
    assert_eq!(fused.served.len(), 4);
    assert!(
        batch_tenants(&fused).values().any(|ts| {
            ts.contains(&0) && ts.contains(&1)
        }),
        "fusing on: the same-model same-epoch pair must share a batch"
    );
    let isolated = run(false);
    assert_eq!(isolated.served.len(), 4, "isolation must not lose work");
    for (b, ts) in batch_tenants(&isolated) {
        let first = ts[0];
        assert!(
            ts.iter().all(|&t| t == first),
            "isolation on: batch {b} mixes tenants {ts:?}"
        );
    }
}

/// Determinism and per-tenant accounting consistency: a two-tenant mixed
/// run is bit-identical across repeats — including the serialized
/// per-tenant JSON — and the tenant views tie out against the aggregate
/// ledgers.
#[test]
fn tenant_views_are_deterministic_and_tie_out() {
    let a = WorkloadSpec::ratio(0.7, 16, 21).generate();
    let b = WorkloadSpec::ratio(0.3, 16, 22)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let wl = Workload::merge_tenants(&[(0, a), (1, b)]);
    let tcfg = || {
        TenancyConfig::new(vec![
            TenantSpec::weighted("gold", 3).with_quota(8).with_class(1),
            TenantSpec::weighted("silver", 1).with_floor(1),
        ])
        .with_depth(4)
    };
    let run = || engine(2).with_tenancy(tcfg()).run(&wl);
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
    assert_eq!(
        r1.served.iter().map(|s| (s.request_id, s.tenant, s.end)).collect::<Vec<_>>(),
        r2.served.iter().map(|s| (s.request_id, s.tenant, s.end)).collect::<Vec<_>>(),
    );
    // The per-tenant views partition the aggregate ledgers exactly.
    assert_eq!(r1.tenant_served(0) + r1.tenant_served(1), r1.served.len());
    assert_eq!(r1.tenant_shed(0) + r1.tenant_shed(1), r1.shed.len());
    assert_eq!(r1.tenant_ops(0) + r1.tenant_ops(1), r1.served.iter().map(|s| s.ops).sum());
    for t in 0..2u32 {
        assert_eq!(r1.tenant_requests(t), r1.tenant_served(t) + r1.tenant_shed(t));
        assert!((0.0..=1.0).contains(&r1.tenant_miss_rate(t)));
        assert!((0.0..=1.0).contains(&r1.tenant_shed_rate(t)));
    }
    // The counters agree with the report's own ledgers.
    assert_eq!(r1.tenant_counters.len(), 2);
    for t in 0..2usize {
        assert_eq!(r1.tenant_counters[t].admitted, r1.tenant_served(t as u32) as u64);
        assert_eq!(r1.tenant_counters[t].completed, r1.tenant_served(t as u32) as u64);
        assert_eq!(r1.tenant_counters[t].shed, r1.tenant_shed(t as u32) as u64);
    }
}
