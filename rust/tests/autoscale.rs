//! Serve-layer autoscaling test suite.
//!
//! Adversarial coverage for the backlog-driven autoscaler: `Off` must keep
//! the fixed-fleet report shape bit for bit, the whole ArrivalModel ×
//! AdmissionPolicy × AutoscalePolicy grid must be deterministic, no request
//! may be lost across a power-down drain, the hysteresis contract (no flap
//! within the dwell window, `min_active` never violated) must hold on real
//! traffic, and autoscaled static energy must never exceed the fixed-fleet
//! baseline for any seed. The `Backlog` arithmetic the controller decides
//! on gets its own property suite (the fold identity and `note_admitted`
//! monotonicity), quickcheck-style via `util::quick`.

use hsv::balancer::{Backlog, DispatchPolicy, LoadBalancer};
use hsv::cluster::SvCluster;
use hsv::config::{HardwareConfig, SimConfig};
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AdmissionPolicy, AutoscalePolicy, BatchPolicy, ScaleDirection, ServeConfig, ServeEngine,
    SloPolicy,
};
use hsv::util::json::Json;
use hsv::util::quick;
use hsv::workload::{ArrivalModel, ModelRegistry, WorkloadRequest, WorkloadSpec};

fn engine(clusters: u32, autoscale: AutoscalePolicy) -> ServeEngine {
    ServeEngine::new(
        HardwareConfig::small().with_clusters(clusters),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig {
            policy: DispatchPolicy::LeastLoaded,
            slo: SloPolicy::default(),
            batch: BatchPolicy::Off,
            admission: AdmissionPolicy::Open,
            autoscale,
            ..Default::default()
        },
    )
}

fn threshold(up: usize, down: usize, min_active: u32, dwell: u64, warmup: u64) -> AutoscalePolicy {
    AutoscalePolicy::Threshold { up, down, min_active, dwell, warmup }
}

fn json_keys(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(m) => m.keys().cloned().collect(),
        _ => panic!("report JSON must be an object"),
    }
}

/// `Off` autoscaling must reproduce the fixed-fleet (PR 3) report exactly:
/// the JSON carries precisely the pre-autoscaling key set — no autoscale
/// keys, no energy keys — the powered ledger reads "every cluster, whole
/// span", and the actual static energy equals the fixed-fleet baseline as
/// the same meter reading, not merely a close value.
#[test]
fn off_autoscale_keeps_the_fixed_fleet_report_shape() {
    let wl = WorkloadSpec::ratio(0.5, 24, 7)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let rep = engine(3, AutoscalePolicy::Off).run(&wl);
    let mut keys = json_keys(&rep.to_json());
    keys.sort();
    let mut expected: Vec<String> = [
        "hw",
        "scheduler",
        "policy",
        "workload",
        "requests",
        "makespan_cycles",
        "tops",
        "goodput_tops",
        "utilization",
        "mean_latency_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p999_ms",
        "deadline_miss_rate",
        "slo_cnn_ms",
        "slo_transformer_ms",
        "epochs",
        "decisions",
        "miss_rate_cnn",
        "miss_rate_transformer",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    expected.sort();
    assert_eq!(keys, expected, "Off report JSON grew or lost keys vs the fixed-fleet engine");
    assert!(!rep.to_json().to_pretty().contains("autoscale"));
    assert_eq!(rep.powered_cycles, vec![rep.makespan; 3]);
    assert_eq!(rep.active_cluster_cycles(), 3 * rep.makespan);
    assert_eq!(rep.scale_ups, 0);
    assert_eq!(rep.scale_downs, 0);
    assert!(rep.scale_log.is_empty());
    assert_eq!(rep.static_energy_j, rep.fixed_fleet_static_energy_j);
    assert_eq!(rep.static_energy_saved_j(), 0.0);
    assert_eq!(rep.static_energy_saved_frac(), 0.0);
}

/// A threshold policy whose knobs can never fire (`up = usize::MAX`,
/// `down = 0`) must schedule exactly like `Off` — same dispatch, same
/// completions — and pay fixed-fleet static energy; the report differs
/// only by the autoscale keys it now carries.
#[test]
fn never_triggering_threshold_schedules_exactly_like_off() {
    let wl = WorkloadSpec::ratio(0.5, 20, 11)
        .with_arrivals(ArrivalModel::diurnal(2_000_000.0))
        .generate();
    let off = engine(3, AutoscalePolicy::Off).run(&wl);
    let never = engine(3, threshold(usize::MAX, 0, 1, 0, 0)).run(&wl);
    let records = |r: &hsv::serve::ServeReport| {
        r.served
            .iter()
            .map(|s| (s.request_id, s.cluster, s.dispatched_at, s.end))
            .collect::<Vec<_>>()
    };
    assert_eq!(records(&off), records(&never), "an idle controller must not steer dispatch");
    assert_eq!(off.makespan, never.makespan);
    assert_eq!(off.decisions, never.decisions);
    assert_eq!(never.scale_ups + never.scale_downs, 0);
    assert_eq!(never.active_cluster_cycles(), 3 * never.makespan);
    // Same physical span, same power: decomposed vs whole-fleet metering
    // may differ only by float associativity.
    let rel = (never.static_energy_j - never.fixed_fleet_static_energy_j).abs()
        / never.fixed_fleet_static_energy_j.max(1e-30);
    assert!(rel < 1e-9, "never-scaled energy must match the fixed fleet (rel {rel})");
    // The report shape differs from Off exactly by the autoscale keys.
    let (off_j, never_j) = (off.to_json(), never.to_json());
    let mut extra: Vec<String> = json_keys(&never_j)
        .into_iter()
        .filter(|k| off_j.get(k).is_none())
        .collect();
    extra.sort();
    let mut expected_extra: Vec<String> = [
        "active_cluster_cycles",
        "admitted_miss_rate",
        "autoscale_down",
        "autoscale_dwell_cycles",
        "autoscale_min_active",
        "autoscale_policy",
        "autoscale_up",
        "autoscale_warmup_cycles",
        "fixed_fleet_static_energy_j",
        "scale_downs",
        "scale_ups",
        "static_energy_j",
        "static_energy_saved_frac",
        "static_energy_saved_j",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    expected_extra.sort();
    assert_eq!(extra, expected_extra);
    for k in json_keys(&off_j) {
        assert_eq!(
            off_j.get(&k).map(|v| v.to_string()),
            never_j.get(&k).map(|v| v.to_string()),
            "shared key {k} diverged"
        );
    }
}

/// Two runs with the same seed must agree bit for bit across the whole
/// ArrivalModel × AdmissionPolicy × AutoscalePolicy grid — including a
/// deliberately flappy zero-dwell controller — and every offered request
/// must be accounted for exactly once (served or shed) across power-down
/// drains and cold wakes.
#[test]
fn autoscale_grid_is_deterministic_and_conserves_requests() {
    let arrivals = [
        ArrivalModel::Poisson,
        ArrivalModel::diurnal(2_000_000.0),
        ArrivalModel::bursty(60_000.0, 6_000.0),
        ArrivalModel::ramp(4.0, 0.5),
    ];
    let admissions = [
        AdmissionPolicy::Open,
        AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 2 },
        AdmissionPolicy::DeadlineFeasible,
    ];
    let autoscales = [
        AutoscalePolicy::Off,
        threshold(2, 1, 1, 50_000, 10_000),
        // Adversarial: flap-prone knobs (scale down whenever depth < 4, up
        // whenever depth > 1, no dwell, instant warm-up).
        threshold(1, 4, 1, 0, 0),
    ];
    for model in arrivals {
        let wl = WorkloadSpec::ratio(0.5, 15, 31).with_arrivals(model).generate();
        for admission in admissions {
            for autoscale in autoscales {
                let run = || {
                    let mut eng = engine(3, autoscale);
                    eng.cfg.admission = admission;
                    eng.run(&wl)
                };
                let a = run();
                let b = run();
                let ctx = format!("{} / {admission:?} / {autoscale:?}", model.name());
                assert_eq!(a.served.len() + a.shed.len(), 15, "{ctx}: request lost");
                let mut ids: Vec<u64> = a
                    .served
                    .iter()
                    .map(|r| r.request_id)
                    .chain(a.shed.iter().map(|r| r.request_id))
                    .collect();
                ids.sort_unstable();
                assert_eq!(ids, (0..15).collect::<Vec<u64>>(), "{ctx}");
                assert!(a.served.iter().all(|r| r.cluster < 3), "{ctx}");
                assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty(), "{ctx}");
                assert_eq!(
                    a.served
                        .iter()
                        .map(|r| (r.request_id, r.cluster, r.end))
                        .collect::<Vec<_>>(),
                    b.served
                        .iter()
                        .map(|r| (r.request_id, r.cluster, r.end))
                        .collect::<Vec<_>>(),
                    "{ctx}"
                );
                assert_eq!(a.powered_cycles, b.powered_cycles, "{ctx}");
                assert_eq!(
                    a.scale_log
                        .iter()
                        .map(|e| (e.cycle, e.cluster, e.direction))
                        .collect::<Vec<_>>(),
                    b.scale_log
                        .iter()
                        .map(|e| (e.cycle, e.cluster, e.direction))
                        .collect::<Vec<_>>(),
                    "{ctx}"
                );
                if !autoscale.enabled() {
                    assert!(
                        !a.to_json().to_pretty().contains("autoscale"),
                        "{ctx}: Off report must not mention autoscaling"
                    );
                }
            }
        }
    }
}

/// Aggressive permanent scale-down (`down = usize::MAX`): the fleet drains
/// to `min_active` while the trace is still arriving, every drained
/// cluster finishes its outstanding work before going cold, and no request
/// is lost or duplicated. The powered ledger must show genuine savings and
/// the deterministic drain order (least outstanding, then higher id).
#[test]
fn permanent_scale_down_conserves_requests_and_saves_energy() {
    let wl = WorkloadSpec::ratio(0.5, 30, 9)
        .with_arrivals(ArrivalModel::bursty(40_000.0, 4_000.0))
        .generate();
    let rep = engine(3, threshold(usize::MAX, usize::MAX, 1, 0, 0)).run(&wl);
    assert_eq!(rep.served.len(), 30, "power-down drains must not lose requests");
    let mut ids: Vec<u64> = rep.served.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..30).collect::<Vec<u64>>());
    assert_eq!(rep.total_ops, wl.total_ops());
    for r in &rep.served {
        assert!(r.dispatched_at >= r.arrival);
        assert!(r.end > r.arrival);
    }
    assert_eq!(rep.scale_ups, 0, "up threshold can never fire");
    assert_eq!(rep.scale_downs, 2, "three clusters drain down to min_active = 1");
    assert!(rep.makespan > 0);
    assert!(
        rep.active_cluster_cycles() < 3 * rep.makespan,
        "drained clusters must stop accruing powered cycles"
    );
    assert!(rep.static_energy_j < rep.fixed_fleet_static_energy_j);
    assert!(rep.static_energy_saved_j() > 0.0);
    let frac = rep.static_energy_saved_frac();
    assert!(frac > 0.0 && frac < 1.0, "saved fraction {frac} out of range");
    let j = rep.to_json();
    assert_eq!(j.get("autoscale_policy").unwrap().as_str(), Some("threshold"));
    assert_eq!(j.get("scale_downs").unwrap().as_f64(), Some(2.0));
    assert!(j.get("static_energy_saved_j").unwrap().as_f64().unwrap() > 0.0);
}

/// Hysteresis contract on real oscillating traffic: within the scale log,
/// a decision never reverses inside the dwell window, and replaying the
/// log never takes the committed capacity (active + warming) below
/// `min_active` or above the fleet size.
#[test]
fn hysteresis_no_flap_within_dwell_and_min_active_never_violated() {
    let wl = WorkloadSpec::ratio(0.5, 40, 13)
        .with_mean_interarrival(100_000.0)
        .with_arrivals(ArrivalModel::bursty(100_000.0, 10_000.0))
        .generate();
    let dwell = 150_000u64;
    let rep = engine(4, threshold(4, 2, 2, dwell, 20_000)).run(&wl);
    assert_eq!(rep.served.len(), 40);
    for w in rep.scale_log.windows(2) {
        if w[0].direction != w[1].direction {
            assert!(
                w[1].cycle >= w[0].cycle + dwell,
                "flap within dwell: {:?} at {} then {:?} at {}",
                w[0].direction,
                w[0].cycle,
                w[1].direction,
                w[1].cycle
            );
        }
    }
    let mut capacity: i64 = 4;
    for e in &rep.scale_log {
        capacity += match e.direction {
            ScaleDirection::Up => 1,
            ScaleDirection::Down => -1,
        };
        assert!(capacity >= 2, "min_active violated at cycle {}", e.cycle);
        assert!(capacity <= 4, "capacity above fleet size at cycle {}", e.cycle);
    }
}

/// Energy monotonicity, property-style: for arbitrary seeds, traffic
/// models, and threshold knobs, autoscaled static energy never exceeds the
/// fixed-fleet baseline, per-cluster powered cycles never exceed the span,
/// and every request is conserved.
#[test]
fn autoscaled_static_energy_never_exceeds_fixed_fleet() {
    quick::check(17, 8, |g| {
        let seed = g.u64_in(0, 1 << 20);
        let model = *g.pick(&[
            ArrivalModel::Poisson,
            ArrivalModel::diurnal(1_000_000.0),
            ArrivalModel::bursty(50_000.0, 5_000.0),
            ArrivalModel::ramp(4.0, 0.25),
        ]);
        let policy = threshold(
            g.usize_in(0, 6),
            g.usize_in(0, 6),
            g.u64_in(1, 3) as u32,
            g.u64_in(0, 200_000),
            g.u64_in(0, 60_000),
        );
        let wl = WorkloadSpec::ratio(0.5, 10, seed).with_arrivals(model).generate();
        let rep = engine(3, policy).run(&wl);
        assert_eq!(rep.served.len(), 10, "seed {seed} / {policy:?}: conservation");
        for (i, &p) in rep.powered_cycles.iter().enumerate() {
            assert!(
                p <= rep.makespan,
                "seed {seed} / {policy:?}: cluster {i} powered {p} > makespan {}",
                rep.makespan
            );
        }
        assert!(rep.active_cluster_cycles() <= 3 * rep.makespan);
        let tolerance = rep.fixed_fleet_static_energy_j * 1e-9 + 1e-15;
        assert!(
            rep.static_energy_j <= rep.fixed_fleet_static_energy_j + tolerance,
            "seed {seed} / {policy:?}: autoscaled static {} > fixed {}",
            rep.static_energy_j,
            rep.fixed_fleet_static_energy_j
        );
        true
    });
}

// ---------------------------------------------------------------------------
// Backlog arithmetic properties (the signal both admission and autoscaling
// decide on).
// ---------------------------------------------------------------------------

/// `LoadBalancer::backlog` must equal the fold of `LoadBalancer::status`
/// for arbitrary cluster states: random fleets, random assignments,
/// clusters stepped to random horizons.
#[test]
fn backlog_equals_the_fold_of_status_for_arbitrary_cluster_states() {
    let reg = ModelRegistry::standard();
    let hw = HardwareConfig::small();
    quick::check(19, 24, |g| {
        let n = g.usize_in(1, 4);
        let mut clusters: Vec<SvCluster> = (0..n as u32)
            .map(|i| SvCluster::new(i, &hw, SchedulerKind::Has, SimConfig::default()))
            .collect();
        for id in 0..g.usize_in(0, 8) as u64 {
            let model = g.usize_in(0, reg.len() - 1) as u32;
            let arrival = g.u64_in(0, 500_000);
            let target = g.usize_in(0, n - 1);
            clusters[target].assign(WorkloadRequest::new(id, model, arrival), &reg);
        }
        // Step a random subset of clusters partway so queued / inflight /
        // booked mixes arise.
        for c in clusters.iter_mut() {
            if g.bool() {
                let horizon = g.u64_in(0, 2_000_000);
                c.run_until(&reg, horizon);
            }
        }
        let rows = LoadBalancer::status(&clusters, &reg);
        let fold = Backlog {
            queued_requests: rows.iter().map(|r| r.queued_requests).sum(),
            inflight_tasks: rows.iter().map(|r| r.inflight_tasks).sum(),
            total_outstanding: rows.iter().map(|r| r.outstanding_cycles).sum(),
            min_outstanding: rows.iter().map(|r| r.outstanding_cycles).min().unwrap_or(0),
        };
        let got = LoadBalancer::backlog(&clusters, &reg);
        assert_eq!(got, fold, "backlog diverged from the status-table fold");
        assert_eq!(got.queue_depth(), fold.queued_requests + fold.inflight_tasks);
        true
    });
}

/// `note_admitted` must keep same-epoch decisions monotone: every fold of
/// an admission into the snapshot raises the queue depth by exactly one
/// and never lowers any outstanding figure — so a request the
/// priority-threshold policy sheds against a snapshot still sheds after
/// more same-epoch admissions (decisions can only get stricter, never
/// flip back to admit).
#[test]
fn note_admitted_keeps_same_epoch_decisions_monotone() {
    let reg = ModelRegistry::standard();
    quick::check(23, 32, |g| {
        let mut b = Backlog {
            queued_requests: g.usize_in(0, 8),
            inflight_tasks: g.usize_in(0, 8),
            total_outstanding: g.u64_in(0, 1 << 40),
            min_outstanding: g.u64_in(0, 1 << 30),
        };
        let floor = g.u64_in(1, 4) as u32;
        let max_depth = g.usize_in(0, 12);
        let mut controller = hsv::serve::AdmissionController::new(
            AdmissionPolicy::PriorityThreshold { floor, max_depth },
            SloPolicy::default(),
            &HardwareConfig::small(),
            &SimConfig::default(),
        );
        let low = WorkloadRequest::new(0, 0, 0).with_priority(floor - 1);
        let mut shed_seen = false;
        for _ in 0..g.usize_in(1, 12) {
            let before = b;
            let decision = controller.decide(&low, 0, 0, &b, &reg);
            if shed_seen {
                assert_eq!(
                    decision,
                    hsv::serve::Decision::Shed(hsv::serve::ShedReason::BelowPriorityFloor),
                    "a below-floor shed flipped back to admit as the backlog grew"
                );
            }
            shed_seen |= decision != hsv::serve::Decision::Admit;
            b.note_admitted(g.u64_in(0, 1 << 20));
            assert_eq!(b.queued_requests, before.queued_requests + 1);
            assert_eq!(b.inflight_tasks, before.inflight_tasks);
            assert!(b.total_outstanding >= before.total_outstanding);
            assert!(b.min_outstanding >= before.min_outstanding);
            assert_eq!(b.queue_depth(), before.queue_depth() + 1);
        }
        true
    });
}
