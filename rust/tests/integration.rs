//! Integration tests across modules: UMF → balancer → cluster → scheduler →
//! simulator → report, plus property tests on scheduler invariants and
//! failure injection on the UMF decoder.

use hsv::balancer::{DispatchPolicy, LoadBalancer};
use hsv::cluster::SvCluster;
use hsv::config::{ClusterConfig, HardwareConfig, SimConfig, SystolicConfig, VectorConfig, MB};
use hsv::coordinator::Coordinator;
use hsv::model::zoo;
use hsv::ops::OpClass;
use hsv::sched::SchedulerKind;
use hsv::umf;
use hsv::util::quick;
use hsv::workload::{ModelRegistry, WorkloadRequest, WorkloadSpec};

/// Full pipeline: UMF-encoded zoo model served through balancer + cluster.
#[test]
fn umf_to_schedule_pipeline() {
    let registry = ModelRegistry::standard();
    let mut lb = LoadBalancer::new(DispatchPolicy::LeastLoaded);
    // Load two models via UMF.
    for (umf_id, name) in [(10u32, "alexnet"), (11, "bert-base")] {
        let g = zoo::by_name(name).unwrap();
        let frame = umf::encode_model(&g, 1, 1, umf_id);
        lb.ingest_umf(&frame.encode(), &registry, 0).unwrap();
    }
    // Submit requests via UMF request frames.
    for i in 0..6u32 {
        let model = if i % 2 == 0 { 10 } else { 11 };
        let req = umf::Frame::request(1, i, model, vec![]);
        lb.ingest_umf(&req.encode(), &registry, (i as u64) * 1000).unwrap();
    }
    let hw = HardwareConfig::small();
    let mut clusters: Vec<SvCluster> = (0..2)
        .map(|i| SvCluster::new(i, &hw, SchedulerKind::Has, SimConfig::default()))
        .collect();
    lb.dispatch(&mut clusters, &registry);
    let done: usize = clusters
        .iter_mut()
        .map(|c| {
            c.run(&registry);
            c.completed()
        })
        .sum();
    assert_eq!(done, 6);
}

/// Scheduler invariants hold over randomized workloads and configs.
#[test]
fn property_schedule_invariants() {
    quick::check(0xFEED, 25, |g| {
        let sa_dim = *g.pick(&[16u32, 32, 64]);
        let sa_count = g.usize_in(1, 4) as u32;
        let vp_lanes = *g.pick(&[16u32, 32, 64]);
        let vp_count = g.usize_in(1, 4) as u32;
        let sm = g.u64_in(4, 64) * MB;
        let hw = HardwareConfig {
            clusters: 1,
            cluster: ClusterConfig {
                systolic: SystolicConfig { dim: sa_dim, count: sa_count },
                vector: VectorConfig { lanes: vp_lanes, count: vp_count },
                shared_mem_bytes: sm,
            },
            clock_ghz: 0.8,
            hbm: Default::default(),
        };
        let ratio = g.f64_in(0.0, 1.0);
        let n = g.usize_in(2, 8);
        let seed = g.rng.next_u64();
        let sched = if g.bool() { SchedulerKind::Has } else { SchedulerKind::RoundRobin };
        let wl = hsv::workload::WorkloadSpec {
            cnn_ratio: ratio,
            requests: n,
            seed,
            mean_interarrival: g.f64_in(1000.0, 100_000.0),
            arrival: hsv::workload::ArrivalModel::Poisson,
        }
        .generate();
        let mut sim = SimConfig::default().with_timeline();
        sim.vp_runs_array_ops = g.bool();
        sim.sublayer_partitioning = g.bool();
        sim.memory_access_scheduling = g.bool();
        let rep = Coordinator::new(hw, sched, sim).run(&wl);

        // Invariant 1: every request completes, after its arrival.
        assert_eq!(rep.completed.len(), n);
        for c in &rep.completed {
            assert!(c.end >= c.arrival, "request {} ends before arrival", c.request_id);
        }
        // Invariant 2: all useful ops are accounted exactly once.
        assert_eq!(rep.total_ops, wl.total_ops());
        // Invariant 3: timeline records never overlap on a processor.
        let mut by_proc: std::collections::BTreeMap<usize, Vec<(u64, u64)>> = Default::default();
        for (cl, t) in &rep.timeline {
            assert_eq!(*cl, 0);
            by_proc.entry(t.proc).or_default().push((t.start, t.end));
        }
        for (_, mut iv) in by_proc {
            iv.sort();
            for w in iv.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlap on processor: {w:?}");
            }
        }
        // Invariant 4: dependencies respected (start >= dep layer end).
        for (_, t) in &rep.timeline {
            let graph = wl.registry.graph(
                wl.requests.iter().find(|r| r.id == t.request_id).unwrap().model_id,
            );
            for &d in &graph.layers[t.layer as usize].deps {
                // dep end is recorded per (request, layer) in layer_end which
                // isn't exposed; rely on per-layer records: every record of a
                // dep layer must end before this start.
                for (_, other) in &rep.timeline {
                    if other.request_id == t.request_id && other.layer == d {
                        assert!(
                            other.end <= t.start,
                            "layer {} starts at {} before dep {} ends at {}",
                            t.layer,
                            t.start,
                            d,
                            other.end
                        );
                    }
                }
            }
        }
        // Invariant 5: energy strictly positive, utilization within [0,1].
        assert!(rep.energy_j > 0.0);
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0);
        true
    });
}

/// RR never assigns array work to vector processors; HAS may.
#[test]
fn rr_keeps_dedicated_assignment_property() {
    quick::check(0xBEEF, 10, |g| {
        let wl = WorkloadSpec::ratio(g.f64_in(0.0, 1.0), g.usize_in(2, 5), g.rng.next_u64())
            .generate();
        let rep = Coordinator::new(
            HardwareConfig::small(),
            SchedulerKind::RoundRobin,
            SimConfig::default().with_timeline(),
        )
        .run(&wl);
        for (_, t) in &rep.timeline {
            if t.op.class() == OpClass::Array {
                assert_eq!(t.kind, hsv::sim::ProcKind::Systolic);
            }
        }
        true
    });
}

/// Fuzz the UMF decoder with structured corruption: never panics, and
/// decodes-to-equal only for untouched frames.
#[test]
fn umf_decoder_failure_injection() {
    let g = zoo::by_name("mobilenetv2").unwrap();
    let frame = umf::encode_model(&g, 3, 4, 5);
    let clean = frame.encode();
    assert!(umf::Frame::decode(&clean).is_ok());
    quick::check(0xDEAD, 300, |gen| {
        let mut bytes = clean.clone();
        match gen.usize_in(0, 2) {
            0 => {
                // random byte flips
                for _ in 0..gen.usize_in(1, 8) {
                    let i = gen.usize_in(0, bytes.len() - 1);
                    bytes[i] ^= gen.rng.next_u64() as u8;
                }
            }
            1 => {
                // truncation
                let cut = gen.usize_in(0, bytes.len() - 1);
                bytes.truncate(cut);
            }
            _ => {
                // garbage append
                bytes.extend((0..gen.usize_in(1, 64)).map(|_| gen.rng.next_u64() as u8));
            }
        }
        let _ = umf::Frame::decode(&bytes); // must not panic
        true
    });
}

/// Load balancing: LeastLoaded spreads a heavy-tailed workload better than
/// pinning everything to one cluster.
#[test]
fn balancer_spreads_load() {
    let registry = ModelRegistry::standard();
    let hw = HardwareConfig::small();
    let heavy = registry.id_of("vgg16").unwrap();
    let light = registry.id_of("mobilenetv2").unwrap();
    let mut lb = LoadBalancer::new(DispatchPolicy::LeastLoaded);
    lb.register_registry(&registry);
    for i in 0..8 {
        let model = if i < 2 { heavy } else { light };
        lb.submit(WorkloadRequest::new(i, model, i * 100), 0).unwrap();
    }
    let mut clusters: Vec<SvCluster> =
        (0..2).map(|i| SvCluster::new(i, &hw, SchedulerKind::Has, SimConfig::default())).collect();
    lb.dispatch(&mut clusters, &registry);
    let counts: Vec<usize> = (0..2)
        .map(|c| lb.request_table.iter().filter(|e| e.cluster == Some(c)).count())
        .collect();
    assert!(counts[0] > 0 && counts[1] > 0, "one cluster starved: {counts:?}");
}

/// Determinism: identical inputs give identical reports.
#[test]
fn simulation_is_deterministic() {
    let wl = WorkloadSpec::ratio(0.5, 8, 99).generate();
    let run = || {
        Coordinator::new(HardwareConfig::small(), SchedulerKind::Has, SimConfig::default())
            .run(&wl)
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.total_ops, b.total_ops);
    assert!((a.energy_j - b.energy_j).abs() < 1e-12);
}

/// The headline ordering holds end-to-end on a reduced workload: HAS ≥ RR
/// in throughput, and HSV beats the GPU model.
#[test]
fn headline_orderings_hold() {
    let wl = WorkloadSpec::ratio(0.6, 12, 5).generate();
    let hw = HardwareConfig::gpu_comparable().with_clusters(1);
    let has = Coordinator::new(hw.clone(), SchedulerKind::Has, SimConfig::default()).run(&wl);
    let rr = Coordinator::new(hw, SchedulerKind::RoundRobin, SimConfig::default()).run(&wl);
    assert!(has.tops() > rr.tops());
    let gpu = hsv::gpu::run_workload(&hsv::gpu::GpuSpec::titan_rtx(), &wl);
    assert!(
        has.tops() / 4.0 > gpu.tops() / 4.0,
        "single-cluster HSV {:.2} should beat proportional GPU share",
        has.tops()
    );
    assert!(has.tops_per_watt() > 5.0 * gpu.tops_per_watt());
}
