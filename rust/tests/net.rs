//! §Front end integration tests: the input-boundary no-panic properties
//! (codec, tenancy spec parser, CLI tokenizer), codec round-trip identity,
//! the front-end-off byte-identity pin, replay exactness against the
//! trace-driven engine, and the closed-loop degradation acceptance run
//! (levers engage before admission sheds; goodput beats shed-only).

use hsv::balancer::DispatchPolicy;
use hsv::config::{HardwareConfig, SimConfig};
use hsv::coordinator::Coordinator;
use hsv::model::ModelFamily;
use hsv::net::{
    decode_frame, ClientSpec, DegradationPolicy, FrameReader, Gateway, InMemoryTransport, Msg,
};
use hsv::obs::ObsPolicy;
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AdmissionPolicy, AutoscalePolicy, BatchPolicy, ServeConfig, ServeEngine, ServeReport,
    SloPolicy, TenancyConfig,
};
use hsv::sim::Cycle;
use hsv::util::cli::Args;
use hsv::util::json::Json;
use hsv::util::quick::{check, Gen};
use hsv::workload::{ArrivalModel, ModelRegistry, Workload, WorkloadRequest, WorkloadSpec};

/// One arbitrary protocol message (all five tags, arbitrary field values).
fn arb_msg(g: &mut Gen) -> Msg {
    match g.usize_in(0, 4) {
        0 => Msg::Hello { client_id: g.u64_in(0, u32::MAX as u64) as u32 },
        1 => Msg::Submit { umf: g.vec(64, |g| g.u64_in(0, 255) as u8) },
        2 => Msg::Infer {
            request_id: g.u64_in(0, 1 << 62),
            model_id: g.u64_in(0, u32::MAX as u64) as u32,
            arrival: g.u64_in(0, 1 << 62),
            priority: g.u64_in(0, u32::MAX as u64) as u32,
            tenant: g.u64_in(0, u32::MAX as u64) as u32,
        },
        3 => Msg::Response {
            request_id: g.u64_in(0, 1 << 62),
            model_id: g.u64_in(0, u32::MAX as u64) as u32,
            end: g.u64_in(0, 1 << 62),
            latency: g.u64_in(0, 1 << 62),
            deadline: g.u64_in(0, 1 << 62),
            met: g.bool(),
            degraded: g.bool(),
        },
        _ => Msg::Feedback {
            request_id: g.u64_in(0, 1 << 62),
            observed_latency: g.u64_in(0, 1 << 62),
            deadline: g.u64_in(0, 1 << 62),
        },
    }
}

/// Satellite: every codec message survives encode ∘ decode exactly, and a
/// frame is consumed to its last byte (strict framing — nothing else
/// round-trips).
#[test]
fn codec_round_trip_is_identity_for_every_message() {
    check(11, 400, |g| {
        let msg = arb_msg(g);
        let bytes = msg.encode();
        match decode_frame(&bytes) {
            Ok(Some((decoded, consumed))) => decoded == msg && consumed == bytes.len(),
            _ => false,
        }
    });
}

/// Satellite: the frame decoder never panics — not on garbage, not on
/// mutated valid frames, not on truncations, and not on any chunking of a
/// byte stream through the incremental reader. `quick::check` treats a
/// panic inside the property as a failure.
#[test]
fn frame_decoder_never_panics_on_arbitrary_input() {
    check(13, 600, |g| {
        // A byte soup: valid frames, mutated frames, truncations, garbage.
        let mut stream: Vec<u8> = Vec::new();
        for _ in 0..g.usize_in(0, 4) {
            match g.usize_in(0, 3) {
                0 => stream.extend_from_slice(&arb_msg(g).encode()),
                1 => {
                    let mut frame = arb_msg(g).encode();
                    let at = g.usize_in(0, frame.len() - 1);
                    frame[at] = frame[at].wrapping_add(g.u64_in(1, 255) as u8);
                    stream.extend_from_slice(&frame);
                }
                2 => {
                    let frame = arb_msg(g).encode();
                    let cut = g.usize_in(0, frame.len());
                    stream.extend_from_slice(&frame[..cut]);
                }
                _ => stream.extend(g.vec(32, |g| g.u64_in(0, 255) as u8)),
            }
        }
        // Direct decode of every suffix start is panic-free.
        let starts = [0, stream.len() / 2, stream.len().saturating_sub(3)];
        for &s in &starts {
            let _ = decode_frame(&stream[s.min(stream.len())..]);
        }
        // The incremental reader survives any chunking; errors poison the
        // stream and reset recovers, never a panic. Each successful
        // next_msg consumes ≥ 5 bytes, so the inner loop terminates.
        let mut rd = FrameReader::new();
        let mut off = 0;
        while off < stream.len() {
            let take = g.usize_in(1, 7).min(stream.len() - off);
            rd.push(&stream[off..off + take]);
            off += take;
            loop {
                match rd.next_msg() {
                    Ok(Some(_)) => {}
                    Ok(None) => break,
                    Err(_) => {
                        rd.reset();
                        break;
                    }
                }
            }
        }
        true
    });
}

/// Satellite: the tenancy spec parser returns `Err` — never panics — on
/// arbitrary input, including multi-byte UTF-8 in any position (the
/// original byte-slicing bug) and duplicate names.
#[test]
fn tenancy_parse_never_panics_on_arbitrary_specs() {
    let alphabet = [
        "a", "b", "tenant", "0", "1", "97", ":", ";", "w", "q", "f", "p", " ", "\t", "é", "Ω",
        "爱", "-", "w3", ":q2",
    ];
    check(17, 500, |g| {
        let spec: String =
            g.vec(24, |g| (*g.pick(&alphabet)).to_string()).concat();
        let _ = TenancyConfig::parse(&spec);
        true
    });
}

/// Satellite: the CLI tokenizer and its non-numeric accessors never panic
/// on arbitrary token streams (flags, values, positionals, unicode, empty
/// strings — in any order).
#[test]
fn args_never_panic_on_arbitrary_token_streams() {
    let vocab = [
        "--batch", "--batch=8", "--", "-x", "gateway", "serve", "12", "--flag=value", "--é=Ω",
        "", "--degrade", "off", "positional", "--slo-slack", "3.5", "--tenants", "a:w1;b:w2",
    ];
    check(19, 500, |g| {
        let tokens: Vec<String> = g.vec(12, |g| (*g.pick(&vocab)).to_string());
        let args = Args::from_iter(tokens);
        let _ = args.subcommand();
        let _ = args.has("batch");
        let _ = args.str("batch", "default");
        let _ = args.str_opt("tenants");
        let _ = args.bool("degrade", true);
        true
    });
}

/// The 21 report keys of the trace-driven engine (pinned since the
/// pre-tenancy shape; tenancy/gateway keys are feature-gated on top).
fn base_report_keys() -> Vec<&'static str> {
    let mut v = vec![
        "hw",
        "scheduler",
        "policy",
        "workload",
        "requests",
        "makespan_cycles",
        "tops",
        "goodput_tops",
        "utilization",
        "mean_latency_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p999_ms",
        "deadline_miss_rate",
        "slo_cnn_ms",
        "slo_transformer_ms",
        "epochs",
        "decisions",
        "miss_rate_cnn",
        "miss_rate_transformer",
    ];
    v.sort_unstable();
    v
}

fn sorted_keys(j: &Json) -> Vec<String> {
    let mut keys: Vec<String> = match j {
        Json::Obj(map) => map.keys().cloned().collect(),
        _ => panic!("report JSON must be an object"),
    };
    keys.sort_unstable();
    keys
}

/// §Front end off-pin: a trace-driven run carries exactly the pre-gateway
/// key set — no `gateway` substring anywhere in the serialized report, no
/// front stats on the struct. A gateway run adds exactly the nine
/// `gateway_*` keys and nothing else.
#[test]
fn front_end_off_reports_stay_byte_identical_to_the_trace_driven_shape() {
    let wl = WorkloadSpec::ratio(0.5, 12, 17).generate();
    let mut eng = ServeEngine::new(
        HardwareConfig::small(),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig::default(),
    );
    let rep = eng.run(&wl);
    assert!(rep.front.is_none(), "the engine never fills front stats on its own");
    let j = rep.to_json();
    assert_eq!(sorted_keys(&j), base_report_keys(), "front-end-off report keys drifted");
    assert!(
        !j.to_pretty().contains("gateway"),
        "front-end-off report mentions the gateway"
    );

    let mut gw_eng = ServeEngine::new(
        HardwareConfig::small(),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig::default(),
    );
    let gw = Gateway::serve(&mut gw_eng, InMemoryTransport::replay(&wl), None);
    let mut expected: Vec<String> =
        base_report_keys().iter().map(|s| s.to_string()).collect();
    expected.extend(
        [
            "gateway_frames_in",
            "gateway_frames_rejected",
            "gateway_submits",
            "gateway_infers",
            "gateway_responses",
            "gateway_feedback",
            "gateway_downgraded_releases",
            "gateway_degrade_transitions",
            "gateway_max_degrade_level",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    expected.sort_unstable();
    assert_eq!(
        sorted_keys(&gw.to_json()),
        expected,
        "gateway report must add exactly the gateway_* keys"
    );
}

/// §Front end replay contract: serving a `Workload` through the in-memory
/// transport (session phase, frame decode, neutral front plane) reproduces
/// the trace-driven report exactly — byte-identical JSON, same decision
/// count, same per-request completions — across traffic models and serve
/// stages (batching + admission exercised too).
#[test]
fn replay_transport_reproduces_the_trace_driven_report_exactly() {
    let cases: Vec<(ArrivalModel, ServeConfig)> = vec![
        (ArrivalModel::Poisson, ServeConfig::default()),
        (
            ArrivalModel::bursty(60_000.0, 6_000.0),
            ServeConfig {
                batch: BatchPolicy::SloAware { max_batch: 4 },
                admission: AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 8 },
                ..ServeConfig::default()
            },
        ),
        (ArrivalModel::ramp(4.0, 0.5), ServeConfig::default()),
    ];
    for (model, cfg) in cases {
        let tag = model.name();
        let wl = WorkloadSpec::ratio(0.5, 20, 17).with_arrivals(model).generate();
        let hw = HardwareConfig::small();
        let trace =
            ServeEngine::new(hw.clone(), SchedulerKind::Has, SimConfig::default(), cfg).run(&wl);
        let mut gw_eng =
            ServeEngine::new(hw, SchedulerKind::Has, SimConfig::default(), cfg);
        let mut gw = Gateway::serve(&mut gw_eng, InMemoryTransport::replay(&wl), None);

        let fs = gw.front.take().expect("gateway runs attach front stats");
        assert_eq!(fs.frames_rejected, 0, "{tag}: replay frames must all decode");
        assert_eq!(fs.infers, wl.requests.len() as u64, "{tag}");

        assert_eq!(
            trace.to_json().to_pretty(),
            gw.to_json().to_pretty(),
            "{tag}: replay report is not byte-identical to the trace-driven report"
        );
        assert_eq!(trace.decisions, gw.decisions, "{tag}");
        assert_eq!(trace.epochs, gw.epochs, "{tag}");
        assert_eq!(trace.served.len(), gw.served.len(), "{tag}");
        for (a, b) in trace.served.iter().zip(&gw.served) {
            assert_eq!(
                (a.request_id, a.end, a.met),
                (b.request_id, b.end, b.met),
                "{tag}: completion streams diverged"
            );
        }
    }
}

/// §Fault tolerance satellite: `FrameReader::reset` is the recovery path
/// after a mid-frame connection drop. A stale half-frame poisons stream
/// alignment; the reconnect resets the reader and decoding resumes exactly
/// at the next frame boundary.
#[test]
fn frame_reader_recovers_after_a_mid_frame_connection_drop() {
    let m1 = Msg::Hello { client_id: 7 };
    let m2 = Msg::Infer { request_id: 1, model_id: 2, arrival: 3, priority: 4, tenant: 5 };
    let m3 = Msg::Feedback { request_id: 9, observed_latency: 10, deadline: 11 };
    let mut rd = FrameReader::new();
    rd.push(&m1.encode());
    assert_eq!(rd.next_msg().unwrap(), Some(m1));
    // The connection drops mid-frame: only the first half of m2 arrives.
    let bytes = m2.encode();
    rd.push(&bytes[..bytes.len() / 2]);
    assert_eq!(rd.next_msg().unwrap(), None, "a frame prefix just waits for more bytes");
    // The reconnect starts a fresh stream position. Without the reset the
    // stale prefix would misalign every subsequent frame.
    rd.reset();
    rd.push(&bytes);
    rd.push(&m3.encode());
    assert_eq!(rd.next_msg().unwrap(), Some(m2));
    assert_eq!(rd.next_msg().unwrap(), Some(m3));
    assert_eq!(rd.next_msg().unwrap(), None);
}

/// §Fault tolerance satellite: for any frame stream and any cut position,
/// a dispatcher-style reader (reset on decode error) over a transport with
/// one truncated delivery decodes every frame completed before the cut, in
/// order, and never panics — the prefix a real client had acknowledged
/// survives the drop.
#[test]
fn truncated_delivery_preserves_the_pre_cut_prefix() {
    check(29, 300, |g| {
        let msgs: Vec<Msg> = (0..g.usize_in(1, 6)).map(|_| arb_msg(g)).collect();
        let mut t = InMemoryTransport::new("cut");
        for (i, m) in msgs.iter().enumerate() {
            t.send_msg(i as Cycle, 0, m);
        }
        let cut = g.usize_in(0, msgs.len() - 1);
        t.truncate_delivery(0, cut as u32).expect("the delivery exists");
        let mut rd = FrameReader::new();
        let mut got: Vec<Msg> = Vec::new();
        for (_, _, bytes) in t.drain_ingress() {
            rd.push(&bytes);
            loop {
                match rd.next_msg() {
                    Ok(Some(m)) => got.push(m),
                    Ok(None) => break,
                    Err(_) => {
                        rd.reset();
                        break;
                    }
                }
            }
        }
        got.len() >= cut && got[..cut] == msgs[..cut]
    });
}

/// §Fault tolerance satellite (`wire` feature): the loopback-TCP gateway
/// smoke. A client thread writes the same deterministic Hello + Infer
/// script the in-memory replay transport schedules, the listener collects
/// it over a real 127.0.0.1 socket into the same byte schedule, and the
/// gateway run must reproduce the trace-driven report byte-identically —
/// the socket layer is I/O-only glue with zero protocol influence.
#[cfg(feature = "wire")]
#[test]
fn loopback_tcp_gateway_reproduces_the_trace_driven_report() {
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    let wl = WorkloadSpec::ratio(0.5, 20, 17).generate();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let mut script: Vec<u8> = Msg::Hello { client_id: 0 }.encode();
    for r in &wl.requests {
        script.extend(
            Msg::Infer {
                request_id: r.id,
                model_id: r.model_id,
                arrival: r.arrival,
                priority: r.priority,
                tenant: r.tenant,
            }
            .encode(),
        );
    }
    let writer = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).expect("connect loopback");
        s.write_all(&script).expect("write script");
    });
    let mut transport =
        hsv::net::socket::collect_listener(listener, &wl.name, 1, 0).expect("collect stream");
    writer.join().expect("client thread");
    // The socket path marks clients feedback-enabled for interactive use;
    // replace with the replay-contract client (no feedback) and the
    // workload's own registry so the run stays on the trace-identical
    // neutral path.
    transport.add_client(ClientSpec { id: 0, feedback: false });
    transport.base_registry = Some(wl.registry.clone());

    let hw = HardwareConfig::small();
    let trace = ServeEngine::new(
        hw.clone(),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig::default(),
    )
    .run(&wl);
    let mut eng =
        ServeEngine::new(hw, SchedulerKind::Has, SimConfig::default(), ServeConfig::default());
    let mut gw = Gateway::serve(&mut eng, transport, None);
    let fs = gw.front.take().expect("gateway runs attach front stats");
    assert_eq!(fs.frames_rejected, 0, "every scripted frame must decode off the socket");
    assert_eq!(fs.infers, wl.requests.len() as u64);
    assert_eq!(
        trace.to_json().to_pretty(),
        gw.to_json().to_pretty(),
        "loopback-TCP report is not byte-identical to the trace-driven report"
    );
}

/// Single-request latency of `id` on one idle cluster (the same
/// calibration primitive `SloPolicy::calibrated` uses).
fn solo_latency(
    registry: &ModelRegistry,
    hw: &HardwareConfig,
    sched: SchedulerKind,
    sim: &SimConfig,
    id: u32,
) -> u64 {
    let wl = Workload {
        name: format!("solo_{id}"),
        cnn_ratio: 0.0,
        seed: 0,
        requests: vec![WorkloadRequest::new(0, id, 0)],
        registry: registry.clone(),
    };
    Coordinator::new(hw.clone().with_clusters(1), sched, sim.clone()).run(&wl).latencies[0]
}

/// Mean single-request latency of a 50/50 family mix over the zoo.
fn mean_service(
    registry: &ModelRegistry,
    hw: &HardwareConfig,
    sched: SchedulerKind,
    sim: &SimConfig,
) -> f64 {
    let mut sum = [0.0f64; 2];
    let mut n = [0u32; 2];
    for id in 0..registry.len() as u32 {
        let fam = match registry.graph(id).family {
            ModelFamily::Cnn => 0,
            ModelFamily::Transformer => 1,
        };
        sum[fam] += solo_latency(registry, hw, sched, sim, id) as f64;
        n[fam] += 1;
    }
    0.5 * (sum[0] / n[0] as f64) + 0.5 * (sum[1] / n[1] as f64)
}

/// §Front end acceptance: under a sustained flash crowd the closed loop
/// steps the ladder up *before* the admission stage sheds anything, holds
/// the admitted-request p99 inside the loosest family SLO, and answers
/// strictly more requests within their SLO than the shed-only baseline —
/// across seeds. Goodput here is the user-facing one (requests answered on
/// time): the model-variant lever deliberately trades useful ops per
/// request for on-time answers, which is the whole point of degrading
/// before shedding.
#[test]
fn closed_loop_degradation_beats_shed_only_under_flash_crowd() {
    let hw = HardwareConfig::small();
    let sim = SimConfig::default();
    let sched = SchedulerKind::Has;
    let registry = ModelRegistry::standard();
    let slack = 8.0;
    let slo = SloPolicy::calibrated(&registry, &hw, sched, &sim, slack);
    // Self-calibrate the overload: 1.6× the fleet's sustainable rate for
    // this exact hardware + zoo, independent of absolute cycle scales.
    let mean_s = mean_service(&registry, &hw, sched, &sim);
    let admission = AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 12 };

    for seed in [5u64, 23, 71] {
        let wl = WorkloadSpec::ratio(0.5, 120, seed)
            .with_mean_interarrival(mean_s / 1.6)
            .generate();

        // Shed-only baseline: the trace-driven engine, same admission gate,
        // no closed loop.
        let base_cfg = ServeConfig {
            policy: DispatchPolicy::LeastLoaded,
            slo,
            batch: BatchPolicy::Off,
            admission,
            autoscale: AutoscalePolicy::Off,
            obs: ObsPolicy::Off,
        };
        let base =
            ServeEngine::new(hw.clone(), sched, sim.clone(), base_cfg).run(&wl);
        assert!(
            !base.shed.is_empty(),
            "seed {seed}: the shed-only baseline never shed — the flash crowd \
             calibration is not overloading the fleet"
        );

        // The closed loop: one feedback-enabled client scripting the same
        // workload, degradation armed, obs on so ladder transitions land in
        // the side-log.
        let mut transport =
            InMemoryTransport::new(&wl.name).with_base_registry(wl.registry.clone());
        transport.add_client(ClientSpec { id: 0, feedback: true });
        transport.send_msg(0, 0, &Msg::Hello { client_id: 0 });
        for r in &wl.requests {
            transport.send_msg(
                r.arrival,
                0,
                &Msg::Infer {
                    request_id: r.id,
                    model_id: r.model_id,
                    arrival: r.arrival,
                    priority: r.priority,
                    tenant: r.tenant,
                },
            );
        }
        let policy = DegradationPolicy {
            engage: 0.5,
            disengage: 0.2,
            min_samples: 6,
            dwell: mean_s as Cycle,
            alpha: 0.3,
        };
        let mut eng = ServeEngine::new(
            hw.clone(),
            sched,
            sim.clone(),
            ServeConfig { obs: ObsPolicy::on(), ..base_cfg },
        );
        let rep = Gateway::serve(&mut eng, transport, Some(policy));
        let fs = rep.front.expect("gateway runs attach front stats");

        // The loop closed and the ladder climbed to the model-variant lever.
        assert!(fs.feedback > 0, "seed {seed}: no feedback frames came back");
        assert!(fs.degrade_transitions >= 1, "seed {seed}: the ladder never moved");
        assert!(
            fs.max_level >= 2 && fs.downgraded_releases > 0,
            "seed {seed}: the model-variant lever never engaged (max level {}, {} downgrades)",
            fs.max_level,
            fs.downgraded_releases
        );

        // Levers engage before admission sheds (if it ever needed to).
        let first_engage = eng
            .obs
            .as_ref()
            .expect("obs was on")
            .degrade_log()
            .first()
            .map(|e| e.cycle)
            .expect("transitions were recorded through the sink");
        if let Some(first_shed) = rep.shed.iter().map(|s| s.decided_at).min() {
            assert!(
                first_engage <= first_shed,
                "seed {seed}: shed at {first_shed} before the first lever at {first_engage}"
            );
        }

        // Admitted p99 stays inside the loosest family SLO.
        let p99 = rep.latency_summary().expect("requests were served").p99;
        let bound = slo.cnn_deadline.max(slo.transformer_deadline) as f64;
        assert!(
            p99 <= bound,
            "seed {seed}: admitted p99 {p99:.0} cycles exceeds the SLO bound {bound:.0}"
        );

        // Goodput (requests answered within their SLO) beats shed-only, and
        // the loop never sheds more than the baseline.
        let met = |r: &ServeReport| r.served.iter().filter(|s| s.met).count();
        assert!(
            met(&rep) > met(&base),
            "seed {seed}: closed loop met {} requests vs shed-only {}",
            met(&rep),
            met(&base)
        );
        assert!(
            rep.shed.len() <= base.shed.len(),
            "seed {seed}: degradation shed more ({}) than the shed-only baseline ({})",
            rep.shed.len(),
            base.shed.len()
        );
    }
}
