//! §Perf equivalence suite — the incremental hot-path machinery must be
//! *bit-identical* to a from-scratch recompute, not merely close:
//!
//! - the incremental `outstanding()` / `Backlog` counters equal the naive
//!   walk after every event of randomized serve traces (all arrival models
//!   × both schedulers × both dispatch policies);
//! - the HAS candidate memo produces the same decision stream as the
//!   cache-off baseline over the full model zoo;
//! - offline and online runs under `SimConfig::naive_recompute` reproduce
//!   the default engine's reports byte for byte;
//! - the fork-join cluster advance (`SimConfig::parallel`) reproduces the
//!   sequential engine byte for byte across the arrival × scheduler grid
//!   with the full serve stack on, at 1/4/64 clusters and 1/2/8 threads,
//!   online and offline.
//!
//! In debug builds the library additionally cross-checks every
//! `outstanding()` read against the naive recompute via `debug_assert`, so
//! every test in the whole suite exercises the equivalence at every
//! observation point, not just the ones sampled here.

use hsv::balancer::{Backlog, DispatchPolicy, LoadBalancer};
use hsv::cluster::SvCluster;
use hsv::config::{HardwareConfig, SimConfig};
use hsv::coordinator::Coordinator;
use hsv::model::zoo;
use hsv::sched::state::ClusterState;
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AdmissionPolicy, AutoscalePolicy, BatchPolicy, ObsPolicy, ServeConfig, ServeEngine, SloPolicy,
    TenancyConfig, TenantSpec,
};
use hsv::util::quick;
use hsv::workload::{ArrivalModel, WorkloadSpec};

fn arrival_models() -> [ArrivalModel; 4] {
    [
        ArrivalModel::Poisson,
        ArrivalModel::diurnal(2_000_000.0),
        ArrivalModel::bursty(60_000.0, 6_000.0),
        ArrivalModel::ramp(4.0, 0.5),
    ]
}

/// Property: after every dispatch/advance event of a randomized serve
/// trace, the incremental load signals exactly equal a from-scratch naive
/// recompute, and the `Backlog` aggregate equals the fold of the status
/// table.
#[test]
fn incremental_counters_equal_naive_recompute_after_every_event() {
    let hw = HardwareConfig::small();
    quick::check(0xFEED_5EED, 18, |g| {
        let arrival = *g.pick(&arrival_models());
        let sched = if g.bool() { SchedulerKind::Has } else { SchedulerKind::RoundRobin };
        let policy =
            if g.bool() { DispatchPolicy::LeastLoaded } else { DispatchPolicy::RoundRobin };
        let n = g.usize_in(3, 12);
        let ratio = g.f64_in(0.0, 1.0);
        let wl = WorkloadSpec::ratio(ratio, n, g.rng.next_u64()).with_arrivals(arrival).generate();
        let ncl = g.usize_in(1, 3) as u32;
        let mut clusters: Vec<SvCluster> = (0..ncl)
            .map(|i| SvCluster::new(i, &hw, sched, SimConfig::default()))
            .collect();
        let mut lb = LoadBalancer::new(policy);
        lb.register_registry(&wl.registry);
        for r in &wl.requests {
            lb.submit(*r, 0).unwrap();
        }
        let check_all = |clusters: &[SvCluster]| {
            for c in clusters {
                assert_eq!(
                    c.outstanding(&wl.registry),
                    c.outstanding_naive(&wl.registry),
                    "outstanding diverged"
                );
                let (ops, count) = c.state.recount_inflight();
                assert_eq!(c.state.inflight_ops_est, ops, "inflight ops counter diverged");
                assert_eq!(c.state.inflight_task_count, count, "task counter diverged");
                assert_eq!(c.inflight_tasks(), count);
                assert_eq!(c.state.has_work(), count > 0);
            }
            let rows = LoadBalancer::status(clusters, &wl.registry);
            let fold = Backlog {
                queued_requests: rows.iter().map(|r| r.queued_requests).sum(),
                inflight_tasks: rows.iter().map(|r| r.inflight_tasks).sum(),
                total_outstanding: rows.iter().map(|r| r.outstanding_cycles).sum(),
                min_outstanding: rows.iter().map(|r| r.outstanding_cycles).min().unwrap_or(0),
            };
            assert_eq!(LoadBalancer::backlog(clusters, &wl.registry), fold);
        };
        check_all(&clusters);
        // Drive the fleet through arbitrary horizon slices; every slice is
        // one "event" boundary (dispatch epoch + scheduler advance).
        let mut horizon = 0u64;
        let mut guard = 0;
        loop {
            if lb.queued() == 0 && clusters.iter().all(|c| c.is_drained()) {
                break;
            }
            lb.dispatch_ready(&mut clusters, &wl.registry, horizon);
            for c in clusters.iter_mut() {
                c.run_until(&wl.registry, horizon);
            }
            check_all(&clusters);
            horizon += g.u64_in(10_000, 250_000);
            guard += 1;
            assert!(guard < 10_000, "trace failed to drain");
        }
        check_all(&clusters);
        true
    });
}

/// The HAS candidate memo must not change a single decision: cache-on and
/// cache-off runs over the full model zoo (two requests of every model,
/// staggered arrivals) produce identical decision counts, timelines, and
/// completion records.
#[test]
fn has_candidate_cache_off_matches_cache_on_over_full_zoo() {
    let hw = HardwareConfig::small();
    let run = |naive: bool| -> ClusterState {
        let sim = if naive {
            SimConfig::default().with_naive_recompute().with_timeline()
        } else {
            SimConfig::default().with_timeline()
        };
        let mut st = ClusterState::new(hw.cluster, hw.hbm, sim);
        let models = zoo::all_models();
        for (i, g) in models.iter().enumerate() {
            st.enqueue_request(g, i as u64, i as u32, 0);
        }
        for (i, g) in models.iter().enumerate() {
            let id = models.len() + i;
            st.enqueue_request(g, id as u64, i as u32, (i as u64 + 1) * 10_000);
        }
        while hsv::sched::has::step(&mut st) {}
        st
    };
    let fast = run(false);
    let naive = run(true);
    assert_eq!(fast.decisions, naive.decisions);
    assert_eq!(fast.makespan, naive.makespan);
    assert_eq!(fast.scheduled_ops, naive.scheduled_ops);
    assert_eq!(fast.timeline.len(), naive.timeline.len());
    for (a, b) in fast.timeline.iter().zip(&naive.timeline) {
        assert_eq!(
            (a.request_id, a.layer, a.sub, a.proc, a.start, a.end),
            (b.request_id, b.layer, b.sub, b.proc, b.start, b.end),
            "timeline diverged between cache-on and cache-off"
        );
    }
    assert_eq!(fast.completed.len(), naive.completed.len());
    for (a, b) in fast.completed.iter().zip(&naive.completed) {
        assert_eq!((a.request_id, a.end, a.ops), (b.request_id, b.end, b.ops));
    }
}

/// Offline coordinator runs under the naive-recompute toggle reproduce the
/// default engine's report byte for byte (both schedulers).
#[test]
fn offline_report_identical_under_naive_recompute() {
    let hw = HardwareConfig::small().with_clusters(2);
    let wl = WorkloadSpec::ratio(0.6, 10, 7).generate();
    for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
        let a = Coordinator::new(hw.clone(), sched, SimConfig::default()).run(&wl);
        let b =
            Coordinator::new(hw.clone(), sched, SimConfig::default().with_naive_recompute())
                .run(&wl);
        assert_eq!(a.makespan, b.makespan, "{sched:?}");
        assert_eq!(a.decisions, b.decisions, "{sched:?}");
        assert_eq!(a.latencies, b.latencies, "{sched:?}");
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{sched:?}");
    }
}

/// Online serve runs under the naive-recompute toggle reproduce the default
/// engine's decision stream and report byte for byte, across every arrival
/// model and both schedulers.
#[test]
fn serve_decision_stream_identical_under_naive_recompute() {
    for arrival in arrival_models() {
        for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
            let wl = WorkloadSpec::ratio(0.5, 12, 33).with_arrivals(arrival).generate();
            let run = |naive: bool| {
                let sim = if naive {
                    SimConfig::default().with_naive_recompute()
                } else {
                    SimConfig::default()
                };
                let hw = HardwareConfig::small().with_clusters(2);
                ServeEngine::new(hw, sched, sim, ServeConfig::default()).run(&wl)
            };
            let a = run(false);
            let b = run(true);
            let tag = format!("{} {sched:?}", arrival.name());
            assert_eq!(a.makespan, b.makespan, "{tag}");
            assert_eq!(a.decisions, b.decisions, "{tag}");
            assert_eq!(a.epochs, b.epochs, "{tag}");
            assert_eq!(
                a.served
                    .iter()
                    .map(|r| (r.request_id, r.cluster, r.dispatched_at, r.end))
                    .collect::<Vec<_>>(),
                b.served
                    .iter()
                    .map(|r| (r.request_id, r.cluster, r.dispatched_at, r.end))
                    .collect::<Vec<_>>(),
                "{tag}"
            );
            assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{tag}");
        }
    }
}

/// The full serve stack (SLO-aware batching + feasibility admission +
/// threshold autoscaling) — the widest decision surface the parallel
/// advance has to keep bit-identical.
fn full_stack() -> ServeConfig {
    ServeConfig {
        policy: DispatchPolicy::LeastLoaded,
        slo: SloPolicy::default(),
        batch: BatchPolicy::SloAware { max_batch: 4 },
        admission: AdmissionPolicy::DeadlineFeasible,
        autoscale: AutoscalePolicy::Threshold {
            up: 4,
            down: 1,
            min_active: 1,
            dwell: 100_000,
            warmup: 25_000,
        },
        obs: ObsPolicy::Off,
    }
}

/// §Parallelism: the fork-join cluster advance (`SimConfig::parallel`)
/// reproduces the sequential engine byte for byte — decision stream, epoch
/// count, served tuples, and the full serialized report — across every
/// arrival model × both schedulers with the full stack on, at 1/4/64
/// clusters and 1/2/8 worker threads. Clusters only interact through the
/// balancer at epoch boundaries and every fold at the barrier runs in
/// cluster-id order, so this grid is the proof the toggle is perf-only.
#[test]
fn parallel_serve_identical_to_sequential_across_grid() {
    for arrival in arrival_models() {
        for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
            let wl = WorkloadSpec::ratio(0.5, 16, 33).with_arrivals(arrival).generate();
            for ncl in [1u32, 4, 64] {
                let hw = HardwareConfig::small().with_clusters(ncl);
                let run = |sim: SimConfig| {
                    ServeEngine::new(hw.clone(), sched, sim, full_stack()).run(&wl)
                };
                let seq = run(SimConfig::default());
                for threads in [1usize, 2, 8] {
                    let par =
                        run(SimConfig::default().with_parallel().with_threads(threads));
                    let tag =
                        format!("{} {sched:?} {ncl}cl {threads}thr", arrival.name());
                    assert_eq!(seq.makespan, par.makespan, "{tag}");
                    assert_eq!(seq.decisions, par.decisions, "{tag}");
                    assert_eq!(seq.epochs, par.epochs, "{tag}");
                    assert_eq!(
                        seq.served
                            .iter()
                            .map(|r| (r.request_id, r.cluster, r.dispatched_at, r.end))
                            .collect::<Vec<_>>(),
                        par.served
                            .iter()
                            .map(|r| (r.request_id, r.cluster, r.dispatched_at, r.end))
                            .collect::<Vec<_>>(),
                        "{tag}"
                    );
                    assert_eq!(
                        seq.to_json().to_string(),
                        par.to_json().to_string(),
                        "{tag}: parallel advance changed the serialized report"
                    );
                }
            }
        }
    }
}

/// §Multi-tenancy determinism grid: tenant mix × arrival model × scheduler
/// × parallel on/off. The tenancy gate, DRR dispatch, and per-tenant report
/// views must be deterministic across repeated runs AND bit-identical
/// between the sequential and fork-join engines at every thread count —
/// decision stream, served tuples (including the tenant tag), and the full
/// serialized report with its per-tenant JSON block.
#[test]
fn tenanted_serve_identical_across_runs_and_thread_counts() {
    // Mix 0: the neutral single tenant (every tenancy code path, no
    // skew). Mix 1: a 3:1 weighted pair with a quota, a floor, isolated
    // batching, and a finite fair-dispatch depth — the widest tenant
    // decision surface.
    let mixes: [(&str, fn() -> TenancyConfig); 2] = [
        ("neutral", TenancyConfig::neutral as fn() -> TenancyConfig),
        ("gold-silver", || {
            TenancyConfig::new(vec![
                TenantSpec::weighted("gold", 3).with_quota(6).with_class(1),
                TenantSpec::weighted("silver", 1).with_floor(1),
            ])
            .with_fuse_across_tenants(false)
            .with_depth(3)
        }),
    ];
    for (mix_name, mix) in mixes {
        for arrival in arrival_models() {
            for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
                let mut wl =
                    WorkloadSpec::ratio(0.5, 16, 47).with_arrivals(arrival).generate();
                let nt = mix().len() as u32;
                for (i, r) in wl.requests.iter_mut().enumerate() {
                    r.tenant = i as u32 % nt;
                }
                let hw = HardwareConfig::small().with_clusters(4);
                let run = |sim: SimConfig| {
                    ServeEngine::new(hw.clone(), sched, sim, full_stack())
                        .with_tenancy(mix())
                        .run(&wl)
                };
                let records = |r: &hsv::serve::ServeReport| {
                    r.served
                        .iter()
                        .map(|s| (s.request_id, s.cluster, s.dispatched_at, s.end, s.tenant))
                        .collect::<Vec<_>>()
                };
                let seq = run(SimConfig::default());
                let again = run(SimConfig::default());
                let tag = format!("{mix_name} {} {sched:?}", arrival.name());
                assert_eq!(records(&seq), records(&again), "{tag}: nondeterministic rerun");
                assert_eq!(
                    seq.to_json().to_string(),
                    again.to_json().to_string(),
                    "{tag}: per-tenant JSON drifted between identical runs"
                );
                for threads in [1usize, 2, 8] {
                    let par =
                        run(SimConfig::default().with_parallel().with_threads(threads));
                    let tag = format!("{tag} {threads}thr");
                    assert_eq!(seq.makespan, par.makespan, "{tag}");
                    assert_eq!(seq.decisions, par.decisions, "{tag}");
                    assert_eq!(seq.epochs, par.epochs, "{tag}");
                    assert_eq!(records(&seq), records(&par), "{tag}");
                    assert_eq!(
                        seq.to_json().to_string(),
                        par.to_json().to_string(),
                        "{tag}: parallel advance changed the tenant report"
                    );
                }
            }
        }
    }
}

/// The parallel and naive-recompute toggles compose: both on still
/// reproduces the default engine byte for byte.
#[test]
fn parallel_composes_with_naive_recompute() {
    let wl = WorkloadSpec::ratio(0.5, 12, 71)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let hw = HardwareConfig::small().with_clusters(4);
    let run = |sim: SimConfig| {
        ServeEngine::new(hw.clone(), SchedulerKind::Has, sim, full_stack()).run(&wl)
    };
    let base = run(SimConfig::default());
    let both = run(SimConfig::default().with_parallel().with_threads(4).with_naive_recompute());
    assert_eq!(base.to_json().to_string(), both.to_json().to_string());
    assert_eq!(base.decisions, both.decisions);
    assert_eq!(base.epochs, both.epochs);
}

/// Offline coordinator runs under the parallel toggle reproduce the
/// sequential report byte for byte (both schedulers, several thread
/// counts, including more workers than clusters).
#[test]
fn offline_report_identical_under_parallel() {
    let wl = WorkloadSpec::ratio(0.6, 12, 7).generate();
    for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
        for ncl in [2u32, 4] {
            let hw = HardwareConfig::small().with_clusters(ncl);
            let a = Coordinator::new(hw.clone(), sched, SimConfig::default()).run(&wl);
            for threads in [1usize, 3, 8] {
                let b = Coordinator::new(
                    hw.clone(),
                    sched,
                    SimConfig::default().with_parallel().with_threads(threads),
                )
                .run(&wl);
                let tag = format!("{sched:?} {ncl}cl {threads}thr");
                assert_eq!(a.makespan, b.makespan, "{tag}");
                assert_eq!(a.decisions, b.decisions, "{tag}");
                assert_eq!(a.latencies, b.latencies, "{tag}");
                assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "{tag}");
            }
        }
    }
}

/// Satellite regression: per-request ops are real everywhere — the
/// scheduler populates `CompletedRequest.ops` from the request's own task
/// queue, matching the registry's precomputed table.
#[test]
fn completed_request_ops_are_real() {
    let wl = WorkloadSpec::ratio(0.5, 8, 21).generate();
    let hw = HardwareConfig::small().with_clusters(2);
    let rep = Coordinator::new(hw, SchedulerKind::Has, SimConfig::default()).run(&wl);
    assert_eq!(rep.completed.len(), 8);
    for r in &rep.completed {
        assert!(r.ops > 0, "request {} has zero ops", r.request_id);
        assert_eq!(r.ops, wl.registry.total_ops(r.model_id));
        assert_eq!(r.ops, wl.registry.graph(r.model_id).total_ops());
    }
    assert_eq!(rep.total_ops, wl.total_ops());
}
