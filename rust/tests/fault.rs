//! §Fault tolerance integration tests.
//!
//! Two standing contracts are pinned here:
//!
//! 1. **Faults off → byte identity.** With no fault spec the engine's
//!    decision streams and report JSON are byte-identical to the pre-fault
//!    engine — the report key set is pinned, and an *empty* spec changes
//!    behavior not at all (it only adds the zeroed `fault_*` keys).
//! 2. **Conservation.** Under any seeded chaos schedule, every released
//!    request completes exactly once or sheds with a typed reason — no
//!    request is lost, duplicated, or silently dropped — deterministically
//!    across repeat runs and across the sequential/parallel engines.

use std::collections::HashMap;

use hsv::config::{HardwareConfig, SimConfig};
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AutoscalePolicy, BatchPolicy, FaultSpec, ServeConfig, ServeEngine, ServeReport, ShedReason,
};
use hsv::util::json::Json;
use hsv::workload::{ArrivalModel, Workload, WorkloadSpec};

/// The 21 report keys of the fault-free default-config engine (the same
/// pin `rust/tests/net.rs` holds for the front end).
fn base_report_keys() -> Vec<&'static str> {
    let mut v = vec![
        "hw",
        "scheduler",
        "policy",
        "workload",
        "requests",
        "makespan_cycles",
        "tops",
        "goodput_tops",
        "utilization",
        "mean_latency_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p999_ms",
        "deadline_miss_rate",
        "slo_cnn_ms",
        "slo_transformer_ms",
        "epochs",
        "decisions",
        "miss_rate_cnn",
        "miss_rate_transformer",
    ];
    v.sort_unstable();
    v
}

/// The nine config-gated fault keys, present exactly when a spec is set.
const FAULT_KEYS: [&str; 9] = [
    "fault_crashes",
    "fault_stalls",
    "fault_slowdowns",
    "fault_warmup_fails",
    "fault_link_drops",
    "fault_reclaimed",
    "fault_retries",
    "fault_sheds",
    "fault_recovered",
];

fn sorted_keys(j: &Json) -> Vec<String> {
    let mut keys: Vec<String> = match j {
        Json::Obj(map) => map.keys().cloned().collect(),
        _ => panic!("report JSON must be an object"),
    };
    keys.sort_unstable();
    keys
}

fn engine(hw: HardwareConfig, sched: SchedulerKind, sim: SimConfig, cfg: ServeConfig) -> ServeEngine {
    ServeEngine::new(hw, sched, sim, cfg)
}

/// Every released request lands exactly once in `served ∪ shed`, and every
/// fault shed carries the typed reason.
fn assert_conserved(tag: &str, wl: &Workload, rep: &ServeReport) {
    let mut seen: HashMap<u64, u32> = HashMap::new();
    for s in &rep.served {
        *seen.entry(s.request_id).or_insert(0) += 1;
    }
    for s in &rep.shed {
        *seen.entry(s.request_id).or_insert(0) += 1;
        if s.reason == ShedReason::ClusterFault {
            assert!(
                rep.faults.is_some(),
                "{tag}: a ClusterFault shed can only come from the injector"
            );
        }
    }
    assert_eq!(
        seen.len(),
        wl.requests.len(),
        "{tag}: served ∪ shed covers a different id set than the trace"
    );
    for r in &wl.requests {
        assert_eq!(
            seen.get(&r.id),
            Some(&1),
            "{tag}: request {} must complete exactly once or shed exactly once",
            r.id
        );
    }
}

/// Contract 1a: the faults-off report carries exactly the pre-fault key
/// set — no `fault` substring anywhere in the serialized JSON.
#[test]
fn faults_off_report_key_set_is_pinned() {
    let wl = WorkloadSpec::ratio(0.5, 12, 17).generate();
    let rep = engine(
        HardwareConfig::small(),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig::default(),
    )
    .run(&wl);
    assert!(rep.faults.is_none(), "the engine never fills fault counters on its own");
    assert_eq!(sorted_keys(&rep.to_json()), base_report_keys(), "faults-off keys drifted");
    assert!(
        !rep.to_json().to_pretty().contains("fault"),
        "faults-off report mentions faults"
    );
}

/// Contract 1b: an *empty* spec is behaviorally identical to no spec —
/// same decisions, epochs, makespan, and completion stream — and the JSON
/// differs by exactly the nine zeroed `fault_*` keys.
#[test]
fn empty_fault_spec_changes_nothing_but_the_gated_keys() {
    let wl = WorkloadSpec::ratio(0.5, 30, 23)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let hw = HardwareConfig::small().with_clusters(3);
    let vanilla = engine(hw.clone(), SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
        .run(&wl);
    let faulted = engine(hw, SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
        .with_faults(FaultSpec::none())
        .run(&wl);

    assert_eq!(vanilla.decisions, faulted.decisions, "decision streams diverged");
    assert_eq!(vanilla.epochs, faulted.epochs);
    assert_eq!(vanilla.makespan, faulted.makespan);
    assert_eq!(vanilla.served.len(), faulted.served.len());
    for (a, b) in vanilla.served.iter().zip(&faulted.served) {
        assert_eq!(
            (a.request_id, a.cluster, a.dispatched_at, a.end, a.met),
            (b.request_id, b.cluster, b.dispatched_at, b.end, b.met),
            "completion streams diverged under an empty spec"
        );
    }

    let fr = faulted.faults.expect("a configured spec always attaches counters");
    assert_eq!(
        (fr.crashes, fr.stalls, fr.slowdowns, fr.warmup_fails, fr.link_drops),
        (0, 0, 0, 0, 0)
    );
    assert_eq!((fr.reclaimed, fr.retries, fr.fault_sheds, fr.recovered), (0, 0, 0, 0));

    let mut expected: Vec<String> = base_report_keys().iter().map(|s| s.to_string()).collect();
    expected.extend(FAULT_KEYS.iter().map(|s| s.to_string()));
    expected.sort_unstable();
    assert_eq!(
        sorted_keys(&faulted.to_json()),
        expected,
        "a fault spec must add exactly the fault_* keys"
    );
}

/// Contract 2: the chaos grid. A schedule mixing an explicit crash, a
/// stall, a straggler, and a seeded mtbf process, over every arrival model
/// × scheduler × sequential/2-thread/8-thread combination: conservation
/// holds, repeat runs are byte-identical, and the parallel engine matches
/// the sequential one byte for byte.
#[test]
fn chaos_schedules_conserve_every_request_deterministically() {
    let spec = FaultSpec::parse(
        "crash:0@400000;stall:1@200000+150000;slow:2@100000+200000x3;\
         mtbf:900000@2500000;seed=9;retry=2;backoff=30000",
    )
    .expect("the chaos spec parses");
    let hw = HardwareConfig::small().with_clusters(4);
    let cfg = ServeConfig {
        batch: BatchPolicy::SloAware { max_batch: 4 },
        ..ServeConfig::default()
    };
    let arrivals: Vec<(&str, ArrivalModel)> = vec![
        ("poisson", ArrivalModel::Poisson),
        ("bursty", ArrivalModel::bursty(50_000.0, 5_000.0)),
        ("ramp", ArrivalModel::ramp(4.0, 0.5)),
    ];
    for (name, model) in arrivals {
        for sched in [SchedulerKind::Has, SchedulerKind::RoundRobin] {
            let tag = format!("{name}/{}", sched.name());
            let wl = WorkloadSpec::ratio(0.5, 60, 29)
                .with_mean_interarrival(30_000.0)
                .with_arrivals(model)
                .generate();
            let run = |threads: usize| -> ServeReport {
                let mut sim = SimConfig::default();
                if threads > 0 {
                    sim.parallel = true;
                    sim.threads = threads;
                }
                engine(hw.clone(), sched, sim, cfg)
                    .with_faults(spec.clone())
                    .run(&wl)
            };
            let seq = run(0);
            assert_conserved(&tag, &wl, &seq);
            let fr = seq.faults.expect("counters attach");
            // At least one crash always fires: the explicit crash:0
            // directive, unless the seeded mtbf process crashed cluster 0
            // first — in which case that crash counted instead. The stall
            // and straggler windows fire at most once each (skipped if the
            // mtbf process killed their cluster before the window opened).
            assert!(fr.crashes >= 1, "{tag}: no crash fired");
            assert!(fr.stalls <= 1 && fr.slowdowns <= 1, "{tag}");
            // Conservation cross-check at the counter level: everything
            // reclaimed either recovered or shed (sheds may also come from
            // the end-of-run sweep of never-dispatched work).
            assert!(
                fr.recovered + fr.fault_sheds >= fr.reclaimed,
                "{tag}: reclaimed work leaked ({} reclaimed, {} recovered, {} shed)",
                fr.reclaimed,
                fr.recovered,
                fr.fault_sheds
            );

            // Determinism: an identical rerun is byte-identical.
            let again = run(0);
            assert_eq!(
                seq.to_json().to_pretty(),
                again.to_json().to_pretty(),
                "{tag}: repeat run diverged"
            );
            // And the fork-join engine takes the same decisions bit for bit.
            for threads in [2usize, 8] {
                let par = run(threads);
                assert_eq!(
                    seq.to_json().to_pretty(),
                    par.to_json().to_pretty(),
                    "{tag}: {threads}-thread run diverged from sequential"
                );
            }
        }
    }
}

/// Stalls and stragglers degrade service without losing work: the windows
/// open and close on schedule, every request still completes (nothing
/// sheds — only crashes lose in-flight work), and the run stays
/// deterministic.
#[test]
fn stall_and_straggler_windows_never_lose_requests() {
    let wl = WorkloadSpec::ratio(0.5, 40, 37)
        .with_mean_interarrival(25_000.0)
        .generate();
    let hw = HardwareConfig::small().with_clusters(2);
    let spec = FaultSpec::parse("stall:0@100000+80000;slow:1@50000+100000x4")
        .expect("spec parses");
    let rep = engine(hw, SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
        .with_faults(spec)
        .run(&wl);
    assert_conserved("stall+slow", &wl, &rep);
    let fr = rep.faults.expect("counters attach");
    assert_eq!((fr.stalls, fr.slowdowns, fr.crashes), (1, 1, 0));
    assert_eq!(fr.reclaimed, 0, "only crashes reclaim work");
    assert_eq!(rep.served.len(), wl.requests.len(), "degraded-not-dead clusters lose nothing");
    assert!(rep.shed.is_empty());
}

/// Recovery off + a fleet-wide crash: everything not already completed
/// sheds with the typed `ClusterFault` reason — nothing hangs, nothing is
/// dropped untyped, and the loop still terminates.
#[test]
fn losing_every_cluster_sheds_the_remainder_with_a_typed_reason() {
    let wl = WorkloadSpec::ratio(0.5, 40, 31)
        .with_mean_interarrival(20_000.0)
        .generate();
    let hw = HardwareConfig::small().with_clusters(2);
    let spec = FaultSpec::parse("crash:0@300000;crash:1@300000;recover=off")
        .expect("spec parses");
    let rep = engine(hw, SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
        .with_faults(spec)
        .run(&wl);
    assert_conserved("all-crash", &wl, &rep);
    let fr = rep.faults.expect("counters attach");
    assert_eq!(fr.crashes, 2);
    assert_eq!(fr.retries, 0, "recover=off never schedules a retry");
    assert_eq!(fr.recovered, 0);
    assert!(!rep.shed.is_empty(), "a dead fleet must shed its backlog");
    assert!(
        rep.shed.iter().all(|s| s.reason == ShedReason::ClusterFault),
        "every fault shed carries the typed reason"
    );
    assert_eq!(fr.fault_sheds, rep.shed.len() as u64);
    assert_eq!(rep.served.len() + rep.shed.len(), wl.requests.len());
}

/// The acceptance bar: against the same mid-run crash, recovery (reclaim +
/// re-dispatch under the retry budget) serves strictly more requests than
/// the shed-on-crash baseline, and the report proves work actually moved —
/// reclaimed > 0 on both, recovered > 0 only with recovery on.
#[test]
fn recovery_beats_the_no_recovery_baseline_after_a_crash() {
    let hw = HardwareConfig::small().with_clusters(2);
    let wl = WorkloadSpec::ratio(0.5, 60, 43)
        .with_mean_interarrival(5_000.0)
        .generate();
    // Calibrate the crash to the middle of the fault-free run, so cluster 0
    // dies with real queued + in-flight work.
    let base = engine(hw.clone(), SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
        .run(&wl);
    assert_eq!(base.served.len(), wl.requests.len());
    let crash_at = base.makespan / 2;

    let run = |recover: &str| -> ServeReport {
        let spec =
            FaultSpec::parse(&format!("crash:0@{crash_at};retry=3;backoff=20000;recover={recover}"))
                .expect("spec parses");
        engine(hw.clone(), SchedulerKind::Has, SimConfig::default(), ServeConfig::default())
            .with_faults(spec)
            .run(&wl)
    };
    let with_recovery = run("on");
    let without = run("off");
    assert_conserved("recover=on", &wl, &with_recovery);
    assert_conserved("recover=off", &wl, &without);

    let fr_on = with_recovery.faults.expect("counters attach");
    let fr_off = without.faults.expect("counters attach");
    assert!(fr_on.reclaimed > 0, "the crash must reclaim in-flight work");
    assert!(fr_off.reclaimed > 0);
    assert!(fr_on.retries > 0);
    assert!(fr_on.recovered > 0, "reclaimed work must complete elsewhere");
    assert!(fr_off.fault_sheds > 0, "the baseline sheds what it cannot retry");
    assert!(
        with_recovery.served.len() > without.served.len(),
        "recovery served {} requests vs {} without — re-dispatch bought nothing",
        with_recovery.served.len(),
        without.served.len()
    );
}

/// Crash × autoscale composition: a crashed cluster goes through the power
/// ledger as an unplanned Cold (its powered cycles stop at the crash) and
/// the autoscaler never re-wakes it — it wakes a spare instead when the
/// backlog demands capacity.
#[test]
fn a_crashed_cluster_powers_off_and_is_never_rewoken() {
    let hw = HardwareConfig::small().with_clusters(3);
    let wl = WorkloadSpec::ratio(0.5, 50, 47)
        .with_mean_interarrival(8_000.0)
        .generate();
    let cfg = ServeConfig {
        autoscale: AutoscalePolicy::Threshold {
            up: 2,
            down: 0,
            min_active: 1,
            dwell: 10_000,
            warmup: 20_000,
        },
        ..ServeConfig::default()
    };
    let probe = engine(hw.clone(), SchedulerKind::Has, SimConfig::default(), cfg).run(&wl);
    let crash_at = probe.makespan / 3;
    let spec = FaultSpec::parse(&format!("crash:0@{crash_at};retry=3;backoff=20000"))
        .expect("spec parses");
    let rep = engine(hw, SchedulerKind::Has, SimConfig::default(), cfg)
        .with_faults(spec)
        .run(&wl);
    assert_conserved("crash+autoscale", &wl, &rep);
    let fr = rep.faults.expect("counters attach");
    assert_eq!(fr.crashes, 1);
    assert!(
        rep.powered_cycles[0] < rep.makespan,
        "the crashed cluster must stop accruing powered cycles ({} vs makespan {})",
        rep.powered_cycles[0],
        rep.makespan
    );
}
