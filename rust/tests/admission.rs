//! Serve-layer admission-control test suite.
//!
//! Adversarial coverage for the admission stage: `Open` must keep the
//! pre-admission report shape bit for bit, the whole ArrivalModel ×
//! BatchPolicy × AdmissionPolicy grid must be deterministic, the
//! deadline-feasible policy must never shed a request the open policy would
//! have completed on time (no false positives — the service-floor estimator
//! is a lower bound by construction), shedding must improve the
//! admitted-only miss rate under flash crowds, and every request offered to
//! the engine must be accounted for exactly once (served or shed).

use hsv::balancer::DispatchPolicy;
use hsv::config::{HardwareConfig, SimConfig};
use hsv::sched::SchedulerKind;
use hsv::serve::{
    AdmissionPolicy, AutoscalePolicy, BatchPolicy, Disposition, ServeConfig, ServeEngine,
    ShedReason, SloPolicy,
};
use hsv::util::json::Json;
use hsv::util::quick;
use hsv::workload::{ArrivalModel, ModelRegistry, Workload, WorkloadRequest, WorkloadSpec};
use std::collections::HashSet;

fn engine(admission: AdmissionPolicy, slo: SloPolicy) -> ServeEngine {
    ServeEngine::new(
        HardwareConfig::small(),
        SchedulerKind::Has,
        SimConfig::default(),
        ServeConfig {
            policy: DispatchPolicy::LeastLoaded,
            slo,
            batch: BatchPolicy::Off,
            admission,
            autoscale: AutoscalePolicy::Off,
            ..Default::default()
        },
    )
}

/// A same-model burst at cycle 0 with alternating priorities (the
/// priority-threshold policy's separable classes).
fn priority_burst(model: &str, n: u64) -> Workload {
    let registry = ModelRegistry::standard();
    let id = registry.id_of(model).unwrap();
    let requests = (0..n)
        .map(|i| WorkloadRequest::new(i, id, 0).with_priority((i % 2) as u32))
        .collect();
    Workload {
        name: format!("{model}_burst{n}"),
        cnn_ratio: 1.0,
        seed: 0,
        requests,
        registry,
    }
}

fn json_keys(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(m) => m.keys().cloned().collect(),
        _ => panic!("report JSON must be an object"),
    }
}

/// `Open` admission must reproduce the pre-admission (PR 2) report exactly:
/// the JSON carries precisely the pre-admission key set — no admission keys,
/// no shed/deferred counters — and every served request is tagged
/// `Admitted`. (The golden metrics snapshot in `tests/batching.rs` pins the
/// values once blessed; this pins the byte-level shape.)
#[test]
fn open_admission_keeps_the_pre_admission_report_shape() {
    let wl = WorkloadSpec::ratio(0.5, 24, 7)
        .with_arrivals(ArrivalModel::bursty(60_000.0, 6_000.0))
        .generate();
    let rep = engine(AdmissionPolicy::Open, SloPolicy::default()).run(&wl);
    let mut keys = json_keys(&rep.to_json());
    keys.sort();
    let mut expected: Vec<String> = [
        "hw",
        "scheduler",
        "policy",
        "workload",
        "requests",
        "makespan_cycles",
        "tops",
        "goodput_tops",
        "utilization",
        "mean_latency_ms",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p999_ms",
        "deadline_miss_rate",
        "slo_cnn_ms",
        "slo_transformer_ms",
        "epochs",
        "decisions",
        "miss_rate_cnn",
        "miss_rate_transformer",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    expected.sort();
    assert_eq!(keys, expected, "Open report JSON grew or lost keys vs the pre-admission engine");
    assert!(rep.shed.is_empty());
    assert_eq!(rep.deferred, 0);
    assert!(rep.served.iter().all(|s| s.disposition == Disposition::Admitted));
    assert_eq!(rep.miss_rate(), rep.admitted_miss_rate(), "the two views coincide under Open");
    assert_eq!(rep.shed_rate(), 0.0);
}

/// Two runs with the same seed must agree bit for bit across the whole
/// ArrivalModel × BatchPolicy × AdmissionPolicy grid, and every offered
/// request must be accounted for exactly once (served or shed).
#[test]
fn admission_grid_is_deterministic_and_conserves_requests() {
    let arrivals = [
        ArrivalModel::Poisson,
        ArrivalModel::diurnal(2_000_000.0),
        ArrivalModel::bursty(60_000.0, 6_000.0),
        ArrivalModel::ramp(4.0, 0.5),
    ];
    let batches = [
        BatchPolicy::Off,
        BatchPolicy::Sized { max_batch: 3, max_wait: 30_000 },
        BatchPolicy::SloAware { max_batch: 4 },
    ];
    let admissions = [
        AdmissionPolicy::Open,
        AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 2 },
        AdmissionPolicy::DeadlineFeasible,
    ];
    for model in arrivals {
        let wl = WorkloadSpec::ratio(0.5, 15, 31).with_arrivals(model).generate();
        for batch in batches {
            for admission in admissions {
                let run = || {
                    ServeEngine::new(
                        HardwareConfig::small(),
                        SchedulerKind::Has,
                        SimConfig::default(),
                        ServeConfig {
                            policy: DispatchPolicy::LeastLoaded,
                            slo: SloPolicy::default(),
                            batch,
                            admission,
                            autoscale: AutoscalePolicy::Off,
                            ..Default::default()
                        },
                    )
                    .run(&wl)
                };
                let a = run();
                let b = run();
                let ctx = format!("{} / {batch:?} / {admission:?}", model.name());
                assert_eq!(a.served.len() + a.shed.len(), 15, "{ctx}: request lost or duplicated");
                let mut ids: Vec<u64> = a
                    .served
                    .iter()
                    .map(|r| r.request_id)
                    .chain(a.shed.iter().map(|r| r.request_id))
                    .collect();
                ids.sort_unstable();
                assert_eq!(ids, (0..15).collect::<Vec<u64>>(), "{ctx}");
                assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty(), "{ctx}");
                assert_eq!(
                    a.served
                        .iter()
                        .map(|r| (r.request_id, r.end, r.disposition))
                        .collect::<Vec<_>>(),
                    b.served
                        .iter()
                        .map(|r| (r.request_id, r.end, r.disposition))
                        .collect::<Vec<_>>(),
                    "{ctx}"
                );
                assert_eq!(
                    a.shed
                        .iter()
                        .map(|r| (r.request_id, r.decided_at, r.reason))
                        .collect::<Vec<_>>(),
                    b.shed
                        .iter()
                        .map(|r| (r.request_id, r.decided_at, r.reason))
                        .collect::<Vec<_>>(),
                    "{ctx}"
                );
                if !admission.enabled() {
                    assert!(a.shed.is_empty(), "{ctx}: Open must never shed");
                    assert!(
                        !a.to_json().to_pretty().contains("admission"),
                        "{ctx}: Open report must not mention admission"
                    );
                }
                if a.served.iter().any(|s| s.disposition == Disposition::Deferred) {
                    assert!(a.deferred > 0, "{ctx}: deferred disposition without defer events");
                }
            }
        }
    }
}

/// No false positives: at light load, the deadline-feasible policy must
/// never shed a request the open policy completed on time at the same seed.
/// The service-floor estimator is a strict lower bound on isolated latency,
/// so an infeasibility shed implies the open engine missed that request too.
#[test]
fn deadline_feasible_never_sheds_what_open_meets() {
    let registry = ModelRegistry::standard();
    let hw = HardwareConfig::small();
    let sim = SimConfig::default();
    // Generous calibrated SLOs (4x the slowest family member) so feasibility
    // margins dwarf the light-load queueing noise.
    let slo = SloPolicy::calibrated(&registry, &hw, SchedulerKind::Has, &sim, 4.0);
    quick::check(11, 5, |g| {
        let seed = g.u64_in(0, 1 << 20);
        let wl = WorkloadSpec::ratio(0.5, 10, seed)
            .with_mean_interarrival(50_000_000.0)
            .generate();
        let open = engine(AdmissionPolicy::Open, slo).run(&wl);
        let df = engine(AdmissionPolicy::DeadlineFeasible, slo).run(&wl);
        let met: HashSet<u64> =
            open.served.iter().filter(|r| r.met).map(|r| r.request_id).collect();
        for s in &df.shed {
            assert!(
                !met.contains(&s.request_id),
                "seed {seed}: shed request {} ({:?}) though Open met its deadline",
                s.request_id,
                s.reason
            );
        }
        assert_eq!(df.served.len() + df.shed.len(), 10, "seed {seed}: conservation");
        true
    });
}

/// Under a flash crowd, shedding doomed work must not make the surviving
/// users worse off: the deadline-feasible admitted-only miss rate is bounded
/// by the open-policy miss rate at the same seed.
#[test]
fn admitted_miss_rate_bounded_by_open_under_flash_crowd() {
    let registry = ModelRegistry::standard();
    let hw = HardwareConfig::small();
    let sim = SimConfig::default();
    // Tight slack + a crowd far beyond sustainable load: the open policy
    // drowns (most requests miss), which is exactly the regime where
    // shedding the doomed tail must pay off.
    let slo = SloPolicy::calibrated(&registry, &hw, SchedulerKind::Has, &sim, 2.0);
    quick::check(13, 4, |g| {
        let seed = g.u64_in(0, 1 << 20);
        let wl = WorkloadSpec::ratio(0.5, 24, seed)
            .with_mean_interarrival(10_000.0)
            .with_arrivals(ArrivalModel::bursty(10_000.0, 1_000.0))
            .generate();
        let open = engine(AdmissionPolicy::Open, slo).run(&wl);
        let df = engine(AdmissionPolicy::DeadlineFeasible, slo).run(&wl);
        assert!(
            df.admitted_miss_rate() <= open.miss_rate() + 1e-9,
            "seed {seed}: admitted miss {:.3} exceeds open miss {:.3}",
            df.admitted_miss_rate(),
            open.miss_rate()
        );
        assert_eq!(df.served.len() + df.shed.len(), 24, "seed {seed}: conservation");
        for s in &df.served {
            if s.disposition == Disposition::Deferred {
                assert!(df.deferred > 0);
                assert!(
                    s.dispatched_at > s.arrival,
                    "a deferred request cannot dispatch at its arrival"
                );
            }
        }
        true
    });
}

/// The priority-threshold policy sheds exactly the below-floor requests that
/// arrive while the fleet is over the depth knob — a fully deterministic
/// hand-built burst: depth grows with each same-cycle admission, so the
/// fourth and later priority-0 offers shed while priority-1 traffic rides
/// through.
#[test]
fn priority_threshold_sheds_low_priority_under_pressure() {
    let wl = priority_burst("alexnet", 10);
    let rep = engine(
        AdmissionPolicy::PriorityThreshold { floor: 1, max_depth: 2 },
        SloPolicy::default(),
    )
    .run(&wl);
    let shed_ids: Vec<u64> = rep.shed.iter().map(|r| r.request_id).collect();
    assert_eq!(shed_ids, vec![4, 6, 8], "exactly the over-knob priority-0 arrivals shed");
    assert!(rep.shed.iter().all(|r| r.reason == ShedReason::BelowPriorityFloor));
    assert!(rep.shed.iter().all(|r| r.priority == 0));
    let mut served_ids: Vec<u64> = rep.served.iter().map(|r| r.request_id).collect();
    served_ids.sort_unstable();
    assert_eq!(served_ids, vec![0, 1, 2, 3, 5, 7, 9]);
    assert!((rep.shed_rate() - 0.3).abs() < 1e-12);
    assert_eq!(rep.shed_rate_for(hsv::model::ModelFamily::Cnn), Some(0.3));
    assert_eq!(rep.shed_rate_for(hsv::model::ModelFamily::Transformer), None);
    // All-requests miss rate counts the shed as misses; the admitted view
    // does not.
    assert!(rep.miss_rate() >= 0.3);
    assert!(rep.admitted_miss_rate() <= rep.miss_rate());
    let j = rep.to_json();
    assert_eq!(j.get("admission_policy").unwrap().as_str(), Some("priority"));
    assert_eq!(j.get("admission_floor").unwrap().as_f64(), Some(1.0));
    assert_eq!(j.get("admission_max_depth").unwrap().as_f64(), Some(2.0));
    assert_eq!(j.get("shed").unwrap().as_f64(), Some(3.0));
    assert_eq!(j.get("shed_rate_cnn").unwrap().as_f64(), Some(0.3));
    assert!(j.get("shed_rate_transformer").is_none());
    assert!(j.get("admitted_miss_rate").is_some());
}

/// Zero deadline headroom under deadline-feasible admission: every request
/// is infeasible on sight, the whole trace sheds, nothing reaches a
/// cluster, and the report's metrics stay well-defined.
#[test]
fn zero_headroom_sheds_the_entire_trace() {
    let wl = WorkloadSpec::ratio(0.5, 8, 3).generate();
    let rep = engine(AdmissionPolicy::DeadlineFeasible, SloPolicy::new(0, 0)).run(&wl);
    assert_eq!(rep.served.len(), 0);
    assert_eq!(rep.shed.len(), 8);
    assert!(rep.shed.iter().all(|r| r.reason == ShedReason::DeadlineInfeasible));
    assert!(rep.shed.iter().all(|r| r.decided_at == r.arrival), "infeasible on sight");
    assert!(rep.shed.iter().all(|r| r.deadline == r.arrival), "zero headroom deadline");
    assert_eq!(rep.deferred, 0, "zero headroom leaves nothing worth deferring");
    assert_eq!(rep.makespan, 0, "shed work must never reach a cluster");
    assert_eq!(rep.miss_rate(), 1.0);
    assert_eq!(rep.admitted_miss_rate(), 0.0, "nobody was admitted");
    assert_eq!(rep.shed_rate(), 1.0);
    assert_eq!(rep.goodput_tops(), 0.0);
    assert_eq!(rep.tops(), 0.0);
    assert_eq!(rep.p50_ms(), 0.0, "no admitted latency distribution");
    let j = rep.to_json();
    assert_eq!(j.get("shed").unwrap().as_f64(), Some(8.0));
    assert_eq!(j.get("deadline_miss_rate").unwrap().as_f64(), Some(1.0));
    assert_eq!(j.get("admitted_miss_rate").unwrap().as_f64(), Some(0.0));
}

/// Admission composes with dynamic batching: deferred-then-admitted
/// requests may join later coalescing queues, and the fan-out still
/// accounts for every offered request exactly once.
#[test]
fn admission_composes_with_batching() {
    let wl = WorkloadSpec::ratio(0.5, 30, 9)
        .with_arrivals(ArrivalModel::bursty(40_000.0, 4_000.0))
        .generate();
    let mut eng = engine(AdmissionPolicy::DeadlineFeasible, SloPolicy::default());
    eng.cfg.batch = BatchPolicy::SloAware { max_batch: 8 };
    let rep = eng.run(&wl);
    assert_eq!(rep.served.len() + rep.shed.len(), 30);
    let mut ids: Vec<u64> = rep
        .served
        .iter()
        .map(|r| r.request_id)
        .chain(rep.shed.iter().map(|r| r.request_id))
        .collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..30).collect::<Vec<u64>>());
    for r in &rep.served {
        assert!(r.dispatched_at >= r.arrival);
        assert!(r.end > r.arrival);
        assert_eq!(r.latency, r.end - r.arrival);
    }
    // Shed work never executes: total ops count served requests only.
    assert_eq!(
        rep.total_ops,
        rep.served
            .iter()
            .map(|r| wl.registry.graph(r.model_id).total_ops())
            .sum::<u64>()
    );
}
